//! Shard-local state + the sharded orchestrator engine.
//!
//! [`ShardEngine`] owns one contiguous slice of the block-level compact
//! domain plus a ghost ring of `ρ×ρ` tiles mirroring its remote Moore
//! neighbors; its sweep is the *same* tile transition the single
//! engine runs ([`crate::ca::squeeze_block::sweep_block`]), just
//! indexed through the shard-remapped neighbor table.
//!
//! [`ShardedSqueezeEngine`] orchestrates: every step is
//! `halo exchange → parallel shard-local sweeps → buffer swap`, with
//! the exchange acting as the inter-step barrier (ghosts always carry
//! the *previous* step's committed state, so shard sweeps never
//! observe a mid-step neighbor). It implements [`Engine`], so it drops
//! into the factory, the differential suite, and the benches unchanged
//! — and it is the first engine whose domain can exceed any single
//! buffer: each shard's slice (plus its halo ring) is all a worker
//! ever touches.
//!
//! [`PackedShardedSqueezeEngine`] is the same decomposition over the
//! bit-planar backend (`ca::bitkernel`): identical partition, halo plan
//! and shard-remapped neighbor tables, with packed tiles
//! (`ρ·⌈ρ/64⌉` words) moved by the exchange and the shard sweeps running
//! the packed word kernel — bit-identical to the packed single engine
//! (and therefore to BB) by the same shared-sweep-body construction.

use std::sync::Arc;

use super::partition::ShardPartition;
use super::plan::{HaloPlan, HaloRoute};
use super::ShardStats;
use crate::ca::bitkernel::{sweep_block_packed, PackedGeom, PackedOutPtr};
use crate::ca::engine::{seeded_alive, Engine};
use crate::ca::grid::{DoubleBuffer, PackedBuffer};
use crate::ca::rule::Rule;
use crate::ca::squeeze::MapPath;
use crate::ca::squeeze_block::{sweep_block, OutPtr};
use crate::fractal::{Coord, FractalSpec};
use crate::maps::block::{BlockCtx, BlockError};
use crate::maps::cache::{BlockMaps, MapCache};
use crate::maps::lambda::lambda;
use crate::tcu::MmaMode;
use crate::util::pool::parallel_for_chunks;

/// One shard: a contiguous run of `nlocal` blocks plus `nghost` ghost
/// tiles, stored as a combined double buffer `[local ++ ghost]` so the
/// sweep indexes one flat slice.
pub struct ShardEngine {
    nlocal: u64,
    nghost: u64,
    /// Per local block: 8 Moore neighbor base slots in the combined
    /// buffer (remapped by the [`HaloPlan`]).
    neighbors: Vec<[u64; 8]>,
    /// Local cells occupy `[0, nlocal·ρ²)`; ghosts follow.
    buf: DoubleBuffer,
}

impl ShardEngine {
    fn new(nghost: u64, neighbors: Vec<[u64; 8]>, tile: u64) -> ShardEngine {
        let nlocal = neighbors.len() as u64;
        ShardEngine {
            nlocal,
            nghost,
            neighbors,
            buf: DoubleBuffer::zeroed((nlocal + nghost) * tile),
        }
    }

    /// Sweep this shard's local blocks (ghosts are read-only inputs)
    /// and swap. `workers` parallelizes *within* the shard.
    fn step(&mut self, block: &BlockCtx, rule: Rule, workers: usize) {
        let tile = block.rho as u64 * block.rho as u64;
        let cur = &self.buf.cur;
        let neighbors = &self.neighbors;
        let out = OutPtr(self.buf.next.as_mut_ptr());
        parallel_for_chunks(self.nlocal, workers, move |start, end| {
            for lb in start..end {
                sweep_block(cur, out, block, &neighbors[lb as usize], lb * tile, rule);
            }
        });
        self.buf.swap();
    }

    /// Live cells in the *local* slice (ghosts are replicas and must
    /// not be counted).
    fn population(&self, tile: u64) -> u64 {
        self.buf.cur[..(self.nlocal * tile) as usize]
            .iter()
            .map(|&b| b as u64)
            .sum()
    }

    /// Blocks owned by this shard.
    pub fn local_blocks(&self) -> u64 {
        self.nlocal
    }

    /// Ghost tiles mirrored from other shards.
    pub fn ghost_blocks(&self) -> u64 {
        self.nghost
    }
}

/// The sharded block-level Squeeze engine (the `sharded-squeeze:<ρ>:<S>`
/// factory variant).
pub struct ShardedSqueezeEngine {
    /// Shared (possibly cached) global map bundle.
    maps: Arc<BlockMaps>,
    part: ShardPartition,
    routes: Vec<HaloRoute>,
    shards: Vec<ShardEngine>,
    /// Per-destination staging for the gather→scatter exchange, sized
    /// to each shard's ghost ring and reused every step.
    stage: Vec<Vec<u8>>,
    rule: Rule,
    workers: usize,
    path: MapPath,
    halo_bytes_per_step: u64,
    plan_table_bytes: u64,
}

impl ShardedSqueezeEngine {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        spec: &FractalSpec,
        r: u32,
        rho: u32,
        shards: u32,
        rule: Rule,
        density: f64,
        seed: u64,
        workers: usize,
        path: MapPath,
    ) -> Result<ShardedSqueezeEngine, BlockError> {
        Self::with_cache(spec, r, rho, shards, rule, density, seed, workers, path, None)
    }

    /// Build the engine, taking the global map bundle from `cache` when
    /// given; the partition and halo plan are derived per engine. An
    /// invalid ρ comes back as `Err` — the factory and service surface
    /// it as an `ERR` line instead of letting a worker panic mid-build.
    #[allow(clippy::too_many_arguments)]
    pub fn with_cache(
        spec: &FractalSpec,
        r: u32,
        rho: u32,
        shards: u32,
        rule: Rule,
        density: f64,
        seed: u64,
        workers: usize,
        path: MapPath,
        cache: Option<&MapCache>,
    ) -> Result<ShardedSqueezeEngine, BlockError> {
        let mma = match path {
            MapPath::Scalar => None,
            MapPath::Tensor(mode) => Some(mode),
        };
        let maps = match cache {
            Some(c) => c.block_maps(spec, r, rho, mma, workers)?,
            None => Arc::new(BlockMaps::build(spec, r, rho, mma, workers)?),
        };
        let part = ShardPartition::new(maps.block.blocks(), shards);
        let plan = HaloPlan::build(&maps, &part);
        let tile = rho as u64 * rho as u64;
        let halo_bytes_per_step = plan.halo_bytes_per_step();
        let plan_table_bytes = plan.table_bytes();
        let HaloPlan {
            routes,
            ghost_counts,
            neighbors,
            ..
        } = plan;
        let mut engines: Vec<ShardEngine> = neighbors
            .into_iter()
            .zip(&ghost_counts)
            .map(|(tables, &nghost)| ShardEngine::new(nghost, tables, tile))
            .collect();
        let stage: Vec<Vec<u8>> = ghost_counts
            .iter()
            .map(|&g| vec![0u8; (g * tile) as usize])
            .collect();
        // Canonical seeding: compact linear index -> expanded -> global
        // slot -> (owning shard, shard-local slot). Identical decisions
        // to the single engine, routed through the partition.
        let full = &maps.full;
        for idx in 0..full.compact.area() {
            if seeded_alive(seed, idx, density) {
                let e = lambda(full, Coord::from_linear(idx, full.compact.w));
                let slot = maps
                    .block
                    .storage_index(e)
                    .expect("fractal cell must have a slot");
                let bidx = slot / tile;
                let s = part.shard_of(bidx);
                let local = (bidx - part.range(s).0) * tile + slot % tile;
                engines[s].buf.cur[local as usize] = 1;
            }
        }
        Ok(ShardedSqueezeEngine {
            maps,
            part,
            routes,
            shards: engines,
            stage,
            rule,
            workers,
            path,
            halo_bytes_per_step,
            plan_table_bytes,
        })
    }

    /// Halo exchange: copy every boundary tile's committed state into
    /// its readers' ghost rings. Gather→scatter through per-destination
    /// staging keeps the copies safe without locking shard pairs.
    fn exchange(&mut self) {
        let tile = (self.maps.block.rho as u64 * self.maps.block.rho as u64) as usize;
        let stage = &mut self.stage;
        let shards = &self.shards;
        for r in &self.routes {
            let from = r.src_block as usize * tile;
            let to = r.ghost_slot as usize * tile;
            stage[r.dst_shard][to..to + tile]
                .copy_from_slice(&shards[r.src_shard].buf.cur[from..from + tile]);
        }
        for (shard, staged) in self.shards.iter_mut().zip(&self.stage) {
            let ghost_base = (shard.nlocal as usize) * tile;
            shard.buf.cur[ghost_base..ghost_base + staged.len()].copy_from_slice(staged);
        }
    }

    /// The shared map bundle (tests / capacity accounting).
    pub fn maps(&self) -> &BlockMaps {
        &self.maps
    }

    /// The block partition this engine runs under.
    pub fn partition(&self) -> &ShardPartition {
        &self.part
    }

    /// Per-shard `(local_blocks, ghost_blocks)` (capacity accounting).
    pub fn shard_sizes(&self) -> Vec<(u64, u64)> {
        self.shards
            .iter()
            .map(|s| (s.local_blocks(), s.ghost_blocks()))
            .collect()
    }
}

impl Engine for ShardedSqueezeEngine {
    fn name(&self) -> String {
        let base = match self.path {
            MapPath::Scalar => "sharded-squeeze",
            MapPath::Tensor(MmaMode::Fp16) => "sharded-squeeze-tcu",
            MapPath::Tensor(MmaMode::F32) => "sharded-squeeze-tcu-f32",
        };
        format!("{base}-rho{}x{}", self.maps.block.rho, self.shards.len())
    }

    fn step(&mut self) {
        // barrier 1: ghosts receive the previous step's committed state
        self.exchange();
        let rule = self.rule;
        let block = &self.maps.block;
        let n = self.shards.len();
        if n == 1 {
            self.shards[0].step(block, rule, self.workers);
            return;
        }
        // the worker budget bounds OS threads even when shards ≫
        // workers: `threads` executors each sweep a contiguous group of
        // shards; when workers exceed the shard count the surplus goes
        // to intra-shard parallelism instead
        let threads = self.workers.max(1).min(n);
        if threads == 1 {
            for shard in &mut self.shards {
                shard.step(block, rule, 1);
            }
            return;
        }
        let inner = (self.workers / n).max(1);
        let group = n.div_ceil(threads);
        // scope join is barrier 2 (no shard starts step t+1 early)
        std::thread::scope(|scope| {
            for shards in self.shards.chunks_mut(group) {
                scope.spawn(move || {
                    for shard in shards {
                        shard.step(block, rule, inner);
                    }
                });
            }
        });
    }

    fn cells(&self) -> u64 {
        self.maps.full.compact.area()
    }

    fn population(&self) -> u64 {
        let tile = self.maps.block.rho as u64 * self.maps.block.rho as u64;
        self.shards.iter().map(|s| s.population(tile)).sum()
    }

    fn memory_bytes(&self) -> u64 {
        // per-shard state (local + ghost, both halves) + the shared
        // adjacency + the remapped per-shard tables — same accounting
        // courtesy the single block engine extends to its table
        let state: u64 = self.shards.iter().map(|s| s.buf.bytes()).sum();
        state + self.maps.table_bytes() + self.plan_table_bytes
    }

    fn cell(&self, idx: u64) -> u8 {
        let full = &self.maps.full;
        let tile = self.maps.block.rho as u64 * self.maps.block.rho as u64;
        let e = lambda(full, Coord::from_linear(idx, full.compact.w));
        let slot = self.maps.block.storage_index(e).expect("fractal cell");
        let bidx = slot / tile;
        let s = self.part.shard_of(bidx);
        let local = (bidx - self.part.range(s).0) * tile + slot % tile;
        self.shards[s].buf.cur[local as usize]
    }

    fn shard_stats(&self) -> Option<ShardStats> {
        Some(ShardStats {
            shards: self.shards.len() as u32,
            halo_bytes_per_step: self.halo_bytes_per_step,
            imbalance: self.part.imbalance(),
        })
    }
}

/// One packed shard: a contiguous run of `nlocal` blocks plus `nghost`
/// ghost tiles, stored as a combined bit-planar double buffer
/// `[local ++ ghost]` (`ρ·⌈ρ/64⌉` words per tile).
pub struct PackedShardEngine {
    nlocal: u64,
    nghost: u64,
    /// Per local block: 8 Moore neighbor base slots in the combined
    /// buffer, in *cell* units exactly as [`HaloPlan`] remapped them —
    /// the packed sweep converts to word bases internally, so the byte
    /// and packed decompositions share one plan.
    neighbors: Vec<[u64; 8]>,
    buf: PackedBuffer,
}

impl PackedShardEngine {
    fn new(nghost: u64, neighbors: Vec<[u64; 8]>, words_per_tile: u64) -> PackedShardEngine {
        let nlocal = neighbors.len() as u64;
        PackedShardEngine {
            nlocal,
            nghost,
            neighbors,
            buf: PackedBuffer::zeroed((nlocal + nghost) * words_per_tile),
        }
    }

    /// Sweep this shard's local blocks through the packed word kernel
    /// (ghosts are read-only inputs) and swap.
    fn step(&mut self, geom: &PackedGeom, rule: Rule, workers: usize) {
        let wpt = geom.words_per_tile;
        let cur = &self.buf.cur;
        let neighbors = &self.neighbors;
        let out = PackedOutPtr(self.buf.next.as_mut_ptr());
        parallel_for_chunks(self.nlocal, workers, move |start, end| {
            for lb in start..end {
                sweep_block_packed(cur, out, geom, &neighbors[lb as usize], lb * wpt, rule);
            }
        });
        self.buf.swap();
    }

    /// Live cells in the *local* slice (ghost replicas excluded) — a
    /// popcount over the local words.
    fn population(&self, words_per_tile: u64) -> u64 {
        self.buf.cur[..(self.nlocal * words_per_tile) as usize]
            .iter()
            .map(|w| w.count_ones() as u64)
            .sum()
    }

    /// Blocks owned by this shard.
    pub fn local_blocks(&self) -> u64 {
        self.nlocal
    }

    /// Ghost tiles mirrored from other shards.
    pub fn ghost_blocks(&self) -> u64 {
        self.nghost
    }
}

/// The sharded bit-planar block engine (the `squeeze-bits:<ρ>:<S>`
/// factory variant): the byte decomposition's partition + halo plan over
/// [`PackedShardEngine`]s, exchanging packed tiles.
pub struct PackedShardedSqueezeEngine {
    /// Shared (possibly cached) global map bundle (scalar-built).
    maps: Arc<BlockMaps>,
    geom: PackedGeom,
    part: ShardPartition,
    routes: Vec<HaloRoute>,
    shards: Vec<PackedShardEngine>,
    /// Per-destination word staging for the gather→scatter exchange.
    stage: Vec<Vec<u64>>,
    rule: Rule,
    workers: usize,
    halo_bytes_per_step: u64,
    plan_table_bytes: u64,
}

impl PackedShardedSqueezeEngine {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        spec: &FractalSpec,
        r: u32,
        rho: u32,
        shards: u32,
        rule: Rule,
        density: f64,
        seed: u64,
        workers: usize,
    ) -> Result<PackedShardedSqueezeEngine, BlockError> {
        Self::with_cache(spec, r, rho, shards, rule, density, seed, workers, None)
    }

    /// Build the engine, taking the global map bundle from `cache` when
    /// given. An invalid ρ comes back as `Err` for the service.
    #[allow(clippy::too_many_arguments)]
    pub fn with_cache(
        spec: &FractalSpec,
        r: u32,
        rho: u32,
        shards: u32,
        rule: Rule,
        density: f64,
        seed: u64,
        workers: usize,
        cache: Option<&MapCache>,
    ) -> Result<PackedShardedSqueezeEngine, BlockError> {
        let maps = match cache {
            Some(c) => c.block_maps(spec, r, rho, None, workers)?,
            None => Arc::new(BlockMaps::build(spec, r, rho, None, workers)?),
        };
        let geom = PackedGeom::new(&maps.block);
        let part = ShardPartition::new(maps.block.blocks(), shards);
        let plan = HaloPlan::build(&maps, &part);
        let wpt = geom.words_per_tile;
        // the packed exchange moves ρ·⌈ρ/64⌉ words per route
        let halo_bytes_per_step =
            plan.routes.len() as u64 * wpt * std::mem::size_of::<u64>() as u64;
        let plan_table_bytes = plan.table_bytes();
        let HaloPlan {
            routes,
            ghost_counts,
            neighbors,
            ..
        } = plan;
        let mut engines: Vec<PackedShardEngine> = neighbors
            .into_iter()
            .zip(&ghost_counts)
            .map(|(tables, &nghost)| PackedShardEngine::new(nghost, tables, wpt))
            .collect();
        let stage: Vec<Vec<u64>> = ghost_counts
            .iter()
            .map(|&g| vec![0u64; (g * wpt) as usize])
            .collect();
        // Canonical seeding: compact linear index -> expanded -> global
        // slot -> (owning shard, shard-local word/bit).
        let tile = rho as u64 * rho as u64;
        let full = &maps.full;
        for idx in 0..full.compact.area() {
            if seeded_alive(seed, idx, density) {
                let e = lambda(full, Coord::from_linear(idx, full.compact.w));
                let slot = maps
                    .block
                    .storage_index(e)
                    .expect("fractal cell must have a slot");
                let bidx = slot / tile;
                let s = part.shard_of(bidx);
                let local = (bidx - part.range(s).0) * tile + slot % tile;
                let (w, bit) = geom.slot_to_word_bit(local);
                engines[s].buf.cur[w as usize] |= 1u64 << bit;
            }
        }
        Ok(PackedShardedSqueezeEngine {
            maps,
            geom,
            part,
            routes,
            shards: engines,
            stage,
            rule,
            workers,
            halo_bytes_per_step,
            plan_table_bytes,
        })
    }

    /// Halo exchange over packed tiles: word copies along the same
    /// static routes the byte engine uses, gather→scatter through
    /// per-destination staging.
    fn exchange(&mut self) {
        let wpt = self.geom.words_per_tile as usize;
        let stage = &mut self.stage;
        let shards = &self.shards;
        for r in &self.routes {
            let from = r.src_block as usize * wpt;
            let to = r.ghost_slot as usize * wpt;
            stage[r.dst_shard][to..to + wpt]
                .copy_from_slice(&shards[r.src_shard].buf.cur[from..from + wpt]);
        }
        for (shard, staged) in self.shards.iter_mut().zip(&self.stage) {
            let ghost_base = (shard.nlocal as usize) * wpt;
            shard.buf.cur[ghost_base..ghost_base + staged.len()].copy_from_slice(staged);
        }
    }

    /// The shared map bundle (tests / capacity accounting).
    pub fn maps(&self) -> &BlockMaps {
        &self.maps
    }

    /// The packed tile geometry (tests / capacity accounting).
    pub fn geom(&self) -> &PackedGeom {
        &self.geom
    }

    /// The block partition this engine runs under.
    pub fn partition(&self) -> &ShardPartition {
        &self.part
    }

    /// Per-shard `(local_blocks, ghost_blocks)` (capacity accounting).
    pub fn shard_sizes(&self) -> Vec<(u64, u64)> {
        self.shards
            .iter()
            .map(|s| (s.local_blocks(), s.ghost_blocks()))
            .collect()
    }
}

impl Engine for PackedShardedSqueezeEngine {
    fn name(&self) -> String {
        format!(
            "sharded-squeeze-bits-rho{}x{}",
            self.maps.block.rho,
            self.shards.len()
        )
    }

    fn step(&mut self) {
        // barrier 1: ghosts receive the previous step's committed state
        self.exchange();
        let rule = self.rule;
        let geom = &self.geom;
        let n = self.shards.len();
        if n == 1 {
            self.shards[0].step(geom, rule, self.workers);
            return;
        }
        // same worker-budget distribution as the byte decomposition
        let threads = self.workers.max(1).min(n);
        if threads == 1 {
            for shard in &mut self.shards {
                shard.step(geom, rule, 1);
            }
            return;
        }
        let inner = (self.workers / n).max(1);
        let group = n.div_ceil(threads);
        // scope join is barrier 2 (no shard starts step t+1 early)
        std::thread::scope(|scope| {
            for shards in self.shards.chunks_mut(group) {
                scope.spawn(move || {
                    for shard in shards {
                        shard.step(geom, rule, inner);
                    }
                });
            }
        });
    }

    fn cells(&self) -> u64 {
        self.maps.full.compact.area()
    }

    fn population(&self) -> u64 {
        let wpt = self.geom.words_per_tile;
        self.shards.iter().map(|s| s.population(wpt)).sum()
    }

    fn memory_bytes(&self) -> u64 {
        let state: u64 = self.shards.iter().map(|s| s.buf.bytes()).sum();
        state + self.maps.table_bytes() + self.plan_table_bytes
    }

    fn cell(&self, idx: u64) -> u8 {
        let full = &self.maps.full;
        let tile = self.maps.block.rho as u64 * self.maps.block.rho as u64;
        let e = lambda(full, Coord::from_linear(idx, full.compact.w));
        let slot = self.maps.block.storage_index(e).expect("fractal cell");
        let bidx = slot / tile;
        let s = self.part.shard_of(bidx);
        let local = (bidx - self.part.range(s).0) * tile + slot % tile;
        let (w, bit) = self.geom.slot_to_word_bit(local);
        ((self.shards[s].buf.cur[w as usize] >> bit) & 1) as u8
    }

    fn shard_stats(&self) -> Option<ShardStats> {
        Some(ShardStats {
            shards: self.shards.len() as u32,
            halo_bytes_per_step: self.halo_bytes_per_step,
            imbalance: self.part.imbalance(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ca::engine::run_and_hash;
    use crate::ca::squeeze_block::SqueezeBlockEngine;
    use crate::fractal::catalog;

    fn reference_hash(spec: &FractalSpec, r: u32, rho: u32, steps: u32) -> u64 {
        let mut sq = SqueezeBlockEngine::new(
            spec,
            r,
            rho,
            Rule::game_of_life(),
            0.4,
            21,
            2,
            MapPath::Scalar,
        )
        .unwrap();
        run_and_hash(&mut sq, steps)
    }

    #[test]
    fn sharded_matches_single_engine_for_1_2_4_shards() {
        let spec = catalog::sierpinski_triangle();
        let (r, rho, steps) = (5, 2, 6);
        let want = reference_hash(&spec, r, rho, steps);
        for shards in [1u32, 2, 4] {
            let mut sh = ShardedSqueezeEngine::new(
                &spec,
                r,
                rho,
                shards,
                Rule::game_of_life(),
                0.4,
                21,
                4,
                MapPath::Scalar,
            )
            .unwrap();
            assert_eq!(run_and_hash(&mut sh, steps), want, "shards={shards}");
        }
    }

    #[test]
    fn sharded_matches_for_s3_fractals_and_any_worker_count() {
        for spec in [catalog::vicsek(), catalog::sierpinski_carpet()] {
            let (r, rho, steps) = (3, 3, 5);
            let want = reference_hash(&spec, r, rho, steps);
            for (shards, workers) in [(2u32, 1usize), (3, 2), (4, 8)] {
                let mut sh = ShardedSqueezeEngine::new(
                    &spec,
                    r,
                    rho,
                    shards,
                    Rule::game_of_life(),
                    0.4,
                    21,
                    workers,
                    MapPath::Scalar,
                )
                .unwrap();
                assert_eq!(
                    run_and_hash(&mut sh, steps),
                    want,
                    "{} shards={shards} workers={workers}",
                    spec.name
                );
            }
        }
    }

    #[test]
    fn many_more_shards_than_workers_stays_correct_and_bounded() {
        // shards ≫ workers: the step loop must distribute shard groups
        // over the worker budget (not thread-per-shard) and still match
        // the single engine bit for bit — including the degenerate
        // one-block-per-shard decomposition
        let spec = catalog::sierpinski_triangle();
        let (r, rho, steps) = (5, 2, 6);
        let want = reference_hash(&spec, r, rho, steps);
        for shards in [27u32, 1_000_000] {
            let mut sh = ShardedSqueezeEngine::new(
                &spec,
                r,
                rho,
                shards,
                Rule::game_of_life(),
                0.4,
                21,
                3,
                MapPath::Scalar,
            )
            .unwrap();
            // 81 blocks at r=5/ρ=2: the request clamps to ≤ 81 shards
            assert!(sh.shard_stats().unwrap().shards <= 81);
            assert_eq!(run_and_hash(&mut sh, steps), want, "shards={shards}");
        }
    }

    #[test]
    fn seed_state_population_and_cells_match_single_engine() {
        let spec = catalog::sierpinski_triangle();
        let single = SqueezeBlockEngine::new(
            &spec,
            5,
            4,
            Rule::game_of_life(),
            0.5,
            9,
            2,
            MapPath::Scalar,
        )
        .unwrap();
        let sharded = ShardedSqueezeEngine::new(
            &spec,
            5,
            4,
            3,
            Rule::game_of_life(),
            0.5,
            9,
            2,
            MapPath::Scalar,
        )
        .unwrap();
        assert_eq!(sharded.cells(), single.cells());
        assert_eq!(sharded.population(), single.population());
        assert_eq!(sharded.state_hash(), single.state_hash());
        for idx in 0..sharded.cells() {
            assert_eq!(sharded.cell(idx), single.cell(idx), "idx={idx}");
        }
    }

    #[test]
    fn shard_stats_report_topology() {
        let spec = catalog::sierpinski_triangle();
        let e = ShardedSqueezeEngine::new(
            &spec,
            5,
            2,
            4,
            Rule::game_of_life(),
            0.4,
            1,
            2,
            MapPath::Scalar,
        )
        .unwrap();
        let stats = e.shard_stats().expect("sharded engine has stats");
        assert_eq!(stats.shards, 4);
        assert!(stats.halo_bytes_per_step > 0);
        assert!(stats.imbalance >= 1.0);
        // a 1-shard decomposition has no halo
        let single = ShardedSqueezeEngine::new(
            &spec,
            5,
            2,
            1,
            Rule::game_of_life(),
            0.4,
            1,
            2,
            MapPath::Scalar,
        )
        .unwrap();
        assert_eq!(single.shard_stats().unwrap().halo_bytes_per_step, 0);
    }

    #[test]
    fn local_state_bytes_sum_to_the_single_engine_buffer() {
        let spec = catalog::sierpinski_triangle();
        let e = ShardedSqueezeEngine::new(
            &spec,
            6,
            4,
            4,
            Rule::game_of_life(),
            0.4,
            7,
            2,
            MapPath::Scalar,
        )
        .unwrap();
        let tile = 16u64;
        let local_cells: u64 = e.shard_sizes().iter().map(|(l, _)| l * tile).sum();
        assert_eq!(local_cells, e.maps().block.stored_cells());
        // engine accounting = state + shared table + remapped tables
        let state: u64 = e
            .shard_sizes()
            .iter()
            .map(|(l, g)| 2 * (l + g) * tile)
            .sum();
        assert_eq!(
            e.memory_bytes(),
            state + e.maps().table_bytes() + e.plan_table_bytes
        );
    }

    #[test]
    fn cached_sharded_engines_share_the_global_bundle() {
        let spec = catalog::vicsek();
        let cache = MapCache::new();
        let a = ShardedSqueezeEngine::with_cache(
            &spec,
            4,
            3,
            2,
            Rule::game_of_life(),
            0.5,
            11,
            2,
            MapPath::Scalar,
            Some(&cache),
        )
        .unwrap();
        let b = ShardedSqueezeEngine::with_cache(
            &spec,
            4,
            3,
            4,
            Rule::game_of_life(),
            0.5,
            11,
            2,
            MapPath::Scalar,
            Some(&cache),
        )
        .unwrap();
        // different shard counts, one interned adjacency
        assert!(Arc::ptr_eq(&a.maps, &b.maps));
        assert_eq!(cache.stats().misses, 1);
        assert_eq!(cache.stats().hits, 1);
    }

    #[test]
    fn packed_sharded_matches_byte_single_engine_for_1_2_4_shards() {
        let spec = catalog::sierpinski_triangle();
        let (r, rho, steps) = (5, 2, 6);
        let want = reference_hash(&spec, r, rho, steps);
        for shards in [1u32, 2, 4] {
            let mut sh = PackedShardedSqueezeEngine::new(
                &spec,
                r,
                rho,
                shards,
                Rule::game_of_life(),
                0.4,
                21,
                4,
            )
            .unwrap();
            assert_eq!(run_and_hash(&mut sh, steps), want, "shards={shards}");
        }
    }

    #[test]
    fn packed_sharded_matches_for_s3_fractals_and_any_worker_count() {
        for spec in [catalog::vicsek(), catalog::sierpinski_carpet()] {
            let (r, rho, steps) = (3, 3, 5);
            let want = reference_hash(&spec, r, rho, steps);
            for (shards, workers) in [(2u32, 1usize), (3, 2), (4, 8)] {
                let mut sh = PackedShardedSqueezeEngine::new(
                    &spec,
                    r,
                    rho,
                    shards,
                    Rule::game_of_life(),
                    0.4,
                    21,
                    workers,
                )
                .unwrap();
                assert_eq!(
                    run_and_hash(&mut sh, steps),
                    want,
                    "{} shards={shards} workers={workers}",
                    spec.name
                );
            }
        }
    }

    #[test]
    fn packed_sharded_seed_state_and_stats_match_packed_single() {
        use crate::ca::bitkernel::PackedSqueezeBlockEngine;
        let spec = catalog::sierpinski_triangle();
        let single =
            PackedSqueezeBlockEngine::new(&spec, 5, 4, Rule::game_of_life(), 0.5, 9, 2).unwrap();
        let sharded =
            PackedShardedSqueezeEngine::new(&spec, 5, 4, 3, Rule::game_of_life(), 0.5, 9, 2)
                .unwrap();
        assert_eq!(sharded.cells(), single.cells());
        assert_eq!(sharded.population(), single.population());
        assert_eq!(sharded.state_hash(), single.state_hash());
        for idx in 0..sharded.cells() {
            assert_eq!(sharded.cell(idx), single.cell(idx), "idx={idx}");
        }
        let stats = sharded.shard_stats().expect("packed sharded has stats");
        assert_eq!(stats.shards, 3);
        assert!(stats.halo_bytes_per_step > 0);
        // packed halo traffic: whole packed tiles (ρ·⌈ρ/64⌉ words) per route
        assert_eq!(stats.halo_bytes_per_step % (4 * 8), 0);
        assert!(stats.imbalance >= 1.0);
    }

    #[test]
    fn packed_local_state_bytes_sum_to_the_packed_single_buffer() {
        let spec = catalog::sierpinski_triangle();
        let e = PackedShardedSqueezeEngine::new(&spec, 6, 4, 4, Rule::game_of_life(), 0.4, 7, 2)
            .unwrap();
        let wpt = e.geom().words_per_tile;
        let local_words: u64 = e.shard_sizes().iter().map(|(l, _)| l * wpt).sum();
        // local packed bytes (one buffer) sum exactly to the packed
        // single-engine buffer — the 1-bit analogue of the byte invariant
        assert_eq!(
            local_words * 8,
            crate::memory::packed_squeeze_bytes(&spec, 6, 4).unwrap()
        );
        let state: u64 = e.shard_sizes().iter().map(|(l, g)| 2 * (l + g) * wpt * 8).sum();
        assert_eq!(
            e.memory_bytes(),
            state + e.maps().table_bytes() + e.plan_table_bytes
        );
    }

    #[test]
    fn packed_sharded_many_more_shards_than_workers_stays_correct() {
        let spec = catalog::sierpinski_triangle();
        let (r, rho, steps) = (5, 2, 6);
        let want = reference_hash(&spec, r, rho, steps);
        let mut sh = PackedShardedSqueezeEngine::new(
            &spec,
            r,
            rho,
            1_000_000,
            Rule::game_of_life(),
            0.4,
            21,
            3,
        )
        .unwrap();
        assert!(sh.shard_stats().unwrap().shards <= 81);
        assert_eq!(run_and_hash(&mut sh, steps), want);
    }

    #[test]
    fn cached_packed_sharded_shares_the_byte_engines_bundle() {
        let spec = catalog::vicsek();
        let cache = MapCache::new();
        let byte = ShardedSqueezeEngine::with_cache(
            &spec,
            4,
            3,
            2,
            Rule::game_of_life(),
            0.5,
            11,
            2,
            MapPath::Scalar,
            Some(&cache),
        )
        .unwrap();
        let packed = PackedShardedSqueezeEngine::with_cache(
            &spec,
            4,
            3,
            2,
            Rule::game_of_life(),
            0.5,
            11,
            2,
            Some(&cache),
        )
        .unwrap();
        assert!(Arc::ptr_eq(&byte.maps, &packed.maps));
        assert_eq!(cache.stats().misses, 1);
        assert_eq!(cache.stats().hits, 1);
        // identical canonical state through both layouts
        assert_eq!(byte.state_hash(), packed.state_hash());
    }
}
