//! Sharded compact-domain subsystem: halo-exchanged domain
//! decomposition over Squeeze blocks.
//!
//! One `SqueezeBlockEngine` owns the whole compact buffer; this module
//! partitions the block-level domain into contiguous shards
//! ([`partition`]), derives a static halo-exchange plan from the cached
//! `BlockMaps` 8-neighbor adjacency ([`plan`]), and steps the shards as
//! parallel local sweeps separated by an exchange barrier ([`engine`]).
//! The orchestrator implements the common [`crate::ca::Engine`] trait,
//! so `engine=sharded-squeeze:<ρ>:<shards>` drops into the factory,
//! the differential suite, and the benches unchanged — and every step
//! stays bit-identical to the single-engine and BB references. This is
//! the seam future distribution/batching work builds on: a shard's
//! slice + ghost ring is all a worker ever touches, so a domain no
//! longer has to fit one engine's buffer.

pub mod engine;
pub mod partition;
pub mod plan;

pub use engine::{
    PackedShardEngine, PackedShardedSqueezeEngine, ShardEngine, ShardedSqueezeEngine,
};
pub use partition::ShardPartition;
pub use plan::{HaloPlan, HaloRoute};

use crate::fractal::FractalSpec;
use crate::maps::block::BlockError;
use crate::maps::cache::{BlockMaps, MapCache};
use crate::tcu::MmaMode;
use std::sync::Arc;

/// Decomposition facts a sharded engine exposes for the coordinator's
/// gauges (`coordinator::metrics`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardStats {
    /// Effective shard count (requests beyond the block count clamp).
    pub shards: u32,
    /// Cross-shard tile bytes copied per step by the halo exchange.
    pub halo_bytes_per_step: u64,
    /// Largest shard over the ideal share (1.0 = perfectly balanced).
    pub imbalance: f64,
}

/// Upper bound on concurrent warmup threads: one lookup per shard is
/// the point, but a client-chosen shard count must never translate
/// into unbounded OS-thread spawns (a spawn failure would panic the
/// serve session — the exact failure mode `JobSpec::validate` exists
/// to prevent). Beyond this bound extra lookups prove nothing anyway:
/// they all hit the same interned entry.
pub const MAX_WARM_THREADS: u32 = 64;

/// Per-shard cache warmup: every shard's worker interns the shared
/// `BlockMaps` bundle concurrently *before step 0*, so no shard pays a
/// table build mid-run and the cache's build-under-lock guarantee keeps
/// the accounting deterministic — exactly one miss, `t − 1` hits,
/// where `t = min(shards, MAX_WARM_THREADS)`.
pub fn warm(
    cache: &MapCache,
    spec: &FractalSpec,
    r: u32,
    rho: u32,
    mma: Option<MmaMode>,
    shards: u32,
    workers: usize,
) -> Result<Arc<BlockMaps>, BlockError> {
    let threads = shards.clamp(1, MAX_WARM_THREADS);
    let mut results: Vec<Result<Arc<BlockMaps>, BlockError>> = Vec::new();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| scope.spawn(move || cache.block_maps(spec, r, rho, mma, workers)))
            .collect();
        for h in handles {
            results.push(h.join().expect("warmup thread panicked"));
        }
    });
    results
        .into_iter()
        .next()
        .expect("at least one warmup lookup")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fractal::catalog;

    #[test]
    fn warmup_interns_once_and_counts_deterministically() {
        let cache = MapCache::new();
        let spec = catalog::sierpinski_triangle();
        let maps = warm(&cache, &spec, 5, 4, None, 4, 2).unwrap();
        assert_eq!(cache.stats().misses, 1);
        assert_eq!(cache.stats().hits, 3);
        // a later engine build hits the warmed entry
        let again = cache.block_maps(&spec, 5, 4, None, 2).unwrap();
        assert!(Arc::ptr_eq(&maps, &again));
        assert_eq!(cache.stats().hits, 4);
    }

    #[test]
    fn warmup_surfaces_invalid_rho() {
        let cache = MapCache::new();
        let spec = catalog::sierpinski_triangle();
        assert!(warm(&cache, &spec, 5, 3, None, 2, 1).is_err());
    }

    #[test]
    fn warmup_thread_count_is_bounded() {
        // a hostile/typo'd shard count must not translate into
        // unbounded OS-thread spawns (and must still warm the cache)
        let cache = MapCache::new();
        let spec = catalog::sierpinski_triangle();
        warm(&cache, &spec, 4, 2, None, 4_000_000, 1).unwrap();
        assert_eq!(cache.stats().misses, 1);
        assert_eq!(cache.stats().hits, (MAX_WARM_THREADS - 1) as u64);
    }
}
