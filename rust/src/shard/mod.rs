//! Sharded compact-domain subsystem: halo-exchanged domain
//! decomposition over Squeeze blocks.
//!
//! One `SqueezeEngine<B>` owns the whole compact buffer; this module
//! partitions the block-level domain into contiguous shards
//! ([`partition`] — uniform or cost-weighted), derives a static
//! halo-exchange plan from the cached `BlockMaps` 8-neighbor adjacency
//! ([`plan`], including per-route rim-consumption masks and the
//! interior/boundary block split), and steps the shards as parallel
//! local sweeps around a gather→scatter exchange ([`engine`] — one
//! generic orchestrator over any `ca::backend::StateBackend`). The
//! exchange ships rim-compacted payloads by default and overlaps with
//! the interior sweeps; both refinements are bit-identical to the
//! serial whole-tile exchange by construction. The orchestrator
//! implements the common [`crate::ca::Engine`] trait, so
//! `engine=sharded-squeeze:<ρ>:<shards>` drops into the factory, the
//! differential suite, and the benches unchanged — and every step stays
//! bit-identical to the single-engine and BB references. This is the
//! seam future distribution/batching work builds on: a shard's slice +
//! ghost ring is all a worker ever touches, so a domain no longer has
//! to fit one engine's buffer.

pub mod engine;
pub mod partition;
pub mod plan;

pub use engine::{PackedShardedSqueezeEngine, Shard, ShardedSqueezeEngine};
pub use partition::ShardPartition;
pub use plan::{HaloPlan, HaloRoute};

use crate::fractal::FractalSpec;
use crate::maps::block::BlockError;
use crate::maps::cache::{BlockMaps, MapCache};
use crate::tcu::MmaMode;
use std::sync::Arc;

/// Decomposition facts a sharded engine exposes for the coordinator's
/// gauges (`coordinator::metrics`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardStats {
    /// Effective shard count (requests beyond the block count clamp).
    pub shards: u32,
    /// Cross-shard bytes actually copied per step by the halo exchange
    /// (rim-compacted when compaction is on).
    pub halo_bytes_per_step: u64,
    /// What the same routes would copy shipping whole tiles — the
    /// pre-compaction baseline the compaction ratio is measured against.
    pub halo_tile_bytes_per_step: u64,
    /// Largest shard over the ideal share (1.0 = perfectly balanced).
    /// Block-count based for uniform partitions, live-cell-weight based
    /// for `shards=auto` cost-weighted partitions.
    pub imbalance: f64,
}

impl ShardStats {
    /// Shipped bytes over whole-tile bytes (1.0 = no compaction win;
    /// defined as 1.0 when there is no halo at all).
    pub fn compaction_ratio(&self) -> f64 {
        if self.halo_tile_bytes_per_step == 0 {
            1.0
        } else {
            self.halo_bytes_per_step as f64 / self.halo_tile_bytes_per_step as f64
        }
    }
}

/// Tuning knobs of the sharded orchestrator. Defaults are the fast
/// path — overlap and compaction change nothing observable except the
/// clock, so they default on; cost-weighted partitioning changes the
/// decomposition (not the results) and is opt-in via `shards=auto:<S>`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardOpts {
    /// Sweep interior blocks concurrently with the halo exchange.
    pub overlap: bool,
    /// Ship only the rim rows/columns readers consume instead of whole
    /// tiles.
    pub compact: bool,
    /// Cost-weighted contiguous partition seeded from per-block live
    /// cells at t=0 (`ShardPartition::balanced`).
    pub balance: bool,
}

impl Default for ShardOpts {
    fn default() -> ShardOpts {
        ShardOpts {
            overlap: true,
            compact: true,
            balance: false,
        }
    }
}

/// Upper bound on concurrent warmup threads: one lookup per shard is
/// the point, but a client-chosen shard count must never translate
/// into unbounded OS-thread spawns (a spawn failure would panic the
/// serve session — the exact failure mode `JobSpec::validate` exists
/// to prevent). Beyond this bound extra lookups prove nothing anyway:
/// they all hit the same interned entry.
pub const MAX_WARM_THREADS: u32 = 64;

/// Per-shard cache warmup: every shard's worker interns the shared
/// `BlockMaps` bundle concurrently *before step 0*, so no shard pays a
/// table build mid-run and the cache's build-under-lock guarantee keeps
/// the accounting deterministic — exactly one miss, `t − 1` hits,
/// where `t = min(shards, MAX_WARM_THREADS)`.
pub fn warm(
    cache: &MapCache,
    spec: &FractalSpec,
    r: u32,
    rho: u32,
    mma: Option<MmaMode>,
    shards: u32,
    workers: usize,
) -> Result<Arc<BlockMaps>, BlockError> {
    let threads = shards.clamp(1, MAX_WARM_THREADS);
    let mut results: Vec<Result<Arc<BlockMaps>, BlockError>> = Vec::new();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| scope.spawn(move || cache.block_maps(spec, r, rho, mma, workers)))
            .collect();
        for h in handles {
            results.push(h.join().expect("warmup thread panicked"));
        }
    });
    results
        .into_iter()
        .next()
        .expect("at least one warmup lookup")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fractal::catalog;

    #[test]
    fn warmup_interns_once_and_counts_deterministically() {
        let cache = MapCache::new();
        let spec = catalog::sierpinski_triangle();
        let maps = warm(&cache, &spec, 5, 4, None, 4, 2).unwrap();
        assert_eq!(cache.stats().misses, 1);
        assert_eq!(cache.stats().hits, 3);
        // a later engine build hits the warmed entry
        let again = cache.block_maps(&spec, 5, 4, None, 2).unwrap();
        assert!(Arc::ptr_eq(&maps, &again));
        assert_eq!(cache.stats().hits, 4);
    }

    #[test]
    fn warmup_surfaces_invalid_rho() {
        let cache = MapCache::new();
        let spec = catalog::sierpinski_triangle();
        assert!(warm(&cache, &spec, 5, 3, None, 2, 1).is_err());
    }

    #[test]
    fn warmup_thread_count_is_bounded() {
        // a hostile/typo'd shard count must not translate into
        // unbounded OS-thread spawns (and must still warm the cache)
        let cache = MapCache::new();
        let spec = catalog::sierpinski_triangle();
        warm(&cache, &spec, 4, 2, None, 4_000_000, 1).unwrap();
        assert_eq!(cache.stats().misses, 1);
        assert_eq!(cache.stats().hits, (MAX_WARM_THREADS - 1) as u64);
    }

    #[test]
    fn compaction_ratio_handles_empty_halo() {
        let none = ShardStats {
            shards: 1,
            halo_bytes_per_step: 0,
            halo_tile_bytes_per_step: 0,
            imbalance: 1.0,
        };
        assert_eq!(none.compaction_ratio(), 1.0);
        let some = ShardStats {
            shards: 4,
            halo_bytes_per_step: 256,
            halo_tile_bytes_per_step: 1024,
            imbalance: 1.0,
        };
        assert!((some.compaction_ratio() - 0.25).abs() < 1e-12);
    }
}
