//! Contiguous partitioning of the block-level compact domain.
//!
//! The block engine stores the compact domain block-major: block slot
//! `b` (row-major over the coarse compact extent) owns cells
//! `[b·ρ², (b+1)·ρ²)`. A shard partition splits `[0, nblocks)` into
//! contiguous ranges of blocks, one per shard, so each shard's state
//! slice is a contiguous sub-range of the single-engine buffer — the
//! same chunking rule `util::pool` uses for tiles, lifted to ownership.
//! Contiguity is what keeps per-shard seeding, hashing, and byte
//! accounting exact: the union of the slices *is* the single-engine
//! buffer, bit for bit.
//!
//! Two constructors exist: [`ShardPartition::new`] cuts uniform block
//! ranges; [`ShardPartition::balanced`] cuts cost-weighted ranges (the
//! `shards=auto:<S>` job key), choosing boundaries that minimize the
//! maximum per-shard weight — by optimality its weighted imbalance never
//! exceeds the uniform split's.

/// A static assignment of coarse blocks to shards: shard `i` owns the
/// half-open block range `range(i)`. Ranges are contiguous, disjoint,
/// cover `[0, nblocks)`, and are never empty — a request for more
/// shards than blocks is clamped, so `shards()` reports the *effective*
/// count.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPartition {
    nblocks: u64,
    /// Uniform chunk size (`None` for weighted partitions, which locate
    /// owners by binary search instead of division).
    chunk: Option<u64>,
    ranges: Vec<(u64, u64)>,
}

impl ShardPartition {
    /// Partition `nblocks` blocks into (at most) `shards` contiguous
    /// ranges of `ceil(nblocks / shards)` blocks each.
    pub fn new(nblocks: u64, shards: u32) -> ShardPartition {
        let want = (shards.max(1) as u64).min(nblocks.max(1));
        let chunk = nblocks.max(1).div_ceil(want);
        let mut ranges = Vec::new();
        let mut start = 0u64;
        while start < nblocks {
            let end = (start + chunk).min(nblocks);
            ranges.push((start, end));
            start = end;
        }
        if ranges.is_empty() {
            ranges.push((0, 0));
        }
        ShardPartition {
            nblocks,
            chunk: Some(chunk),
            ranges,
        }
    }

    /// Cost-weighted partition: cut `[0, nblocks)` into (at most)
    /// `shards` contiguous non-empty ranges minimizing the maximum
    /// per-range weight sum. `weights[b]` is block `b`'s cost (e.g. its
    /// live-cell count at t=0). Falls back to the uniform split when
    /// every weight is zero (no signal to balance on).
    pub fn balanced(nblocks: u64, shards: u32, weights: &[u64]) -> ShardPartition {
        assert_eq!(weights.len() as u64, nblocks, "one weight per block");
        let total: u64 = weights.iter().sum();
        if total == 0 || nblocks == 0 {
            return ShardPartition::new(nblocks, shards);
        }
        let want = (shards.max(1) as u64).min(nblocks);
        let max_w = weights.iter().copied().max().unwrap_or(0);
        // binary search the smallest per-shard capacity that fits
        // `want` greedy contiguous parts
        let (mut lo, mut hi) = (max_w, total);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if greedy_parts(weights, mid) <= want {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        let cap = lo;
        // materialize the greedy cut at the optimal capacity
        let mut ranges = Vec::new();
        let mut start = 0usize;
        let mut acc = 0u64;
        for (b, &w) in weights.iter().enumerate() {
            if b > start && acc + w > cap {
                ranges.push((start as u64, b as u64));
                start = b;
                acc = 0;
            }
            acc += w;
        }
        ranges.push((start as u64, nblocks));
        debug_assert!(ranges.len() as u64 <= want);
        ShardPartition {
            nblocks,
            chunk: None,
            ranges,
        }
    }

    /// Effective number of shards.
    pub fn shards(&self) -> usize {
        self.ranges.len()
    }

    /// Half-open global block range `[start, end)` owned by shard `s`.
    pub fn range(&self, s: usize) -> (u64, u64) {
        self.ranges[s]
    }

    /// Total blocks across all shards.
    pub fn nblocks(&self) -> u64 {
        self.nblocks
    }

    /// Owning shard of a global block index.
    #[inline]
    pub fn shard_of(&self, block: u64) -> usize {
        match self.chunk {
            Some(chunk) => ((block / chunk) as usize).min(self.ranges.len() - 1),
            None => self
                .ranges
                .partition_point(|&(_, end)| end <= block)
                .min(self.ranges.len() - 1),
        }
    }

    /// Load imbalance over *block counts*: largest shard's block count
    /// over the ideal `nblocks / shards` (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        if self.nblocks == 0 {
            return 1.0;
        }
        let max = self
            .ranges
            .iter()
            .map(|(a, b)| b - a)
            .max()
            .unwrap_or(0) as f64;
        max / (self.nblocks as f64 / self.ranges.len() as f64)
    }

    /// Load imbalance over per-block `weights`: largest shard's weight
    /// sum over the ideal `total / shards`. This is the gauge the
    /// cost-weighted partitioner optimizes (1.0 when total weight is 0).
    pub fn weighted_imbalance(&self, weights: &[u64]) -> f64 {
        assert_eq!(weights.len() as u64, self.nblocks, "one weight per block");
        let total: u64 = weights.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let max = self
            .ranges
            .iter()
            .map(|&(a, b)| weights[a as usize..b as usize].iter().sum::<u64>())
            .max()
            .unwrap_or(0) as f64;
        max / (total as f64 / self.ranges.len() as f64)
    }
}

/// Number of contiguous parts a greedy fill with per-part capacity `cap`
/// produces (each part takes blocks while its sum stays ≤ `cap`; a block
/// heavier than `cap` still gets a part to itself, so the count is an
/// upper bound used only above `max(weights)`).
fn greedy_parts(weights: &[u64], cap: u64) -> u64 {
    let mut parts = 1u64;
    let mut acc = 0u64;
    for (b, &w) in weights.iter().enumerate() {
        if b > 0 && acc + w > cap {
            parts += 1;
            acc = 0;
        }
        acc += w;
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn ranges_cover_disjointly_and_shard_of_agrees() {
        for nblocks in [1u64, 3, 7, 81, 100, 6561] {
            for shards in [1u32, 2, 3, 4, 8, 200] {
                let p = ShardPartition::new(nblocks, shards);
                assert!(p.shards() as u64 <= nblocks.max(1));
                let mut covered = 0u64;
                for s in 0..p.shards() {
                    let (a, b) = p.range(s);
                    assert!(a < b, "empty shard {s} for n={nblocks} shards={shards}");
                    assert_eq!(a, covered, "gap before shard {s}");
                    covered = b;
                    for block in a..b {
                        assert_eq!(p.shard_of(block), s);
                    }
                }
                assert_eq!(covered, nblocks);
            }
        }
    }

    #[test]
    fn clamps_to_block_count() {
        let p = ShardPartition::new(3, 16);
        assert_eq!(p.shards(), 3);
        assert!((p.imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_reflects_ragged_tail() {
        // 10 blocks over 4 shards: chunks of 3,3,3,1 -> max 3 vs mean 2.5
        let p = ShardPartition::new(10, 4);
        assert_eq!(p.shards(), 4);
        assert!((p.imbalance() - 1.2).abs() < 1e-12);
        // exact split is perfectly balanced
        let q = ShardPartition::new(8, 4);
        assert!((q.imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn balanced_covers_disjointly_and_shard_of_agrees() {
        let mut prng = Prng::new(0xBA1);
        for nblocks in [1u64, 5, 81, 257] {
            for shards in [1u32, 2, 4, 9, 300] {
                let weights: Vec<u64> = (0..nblocks).map(|_| prng.below(17)).collect();
                let p = ShardPartition::balanced(nblocks, shards, &weights);
                assert!(p.shards() as u64 <= (shards.max(1) as u64).min(nblocks.max(1)));
                let mut covered = 0u64;
                for s in 0..p.shards() {
                    let (a, b) = p.range(s);
                    assert!(a < b, "empty shard {s}");
                    assert_eq!(a, covered);
                    covered = b;
                    for block in a..b {
                        assert_eq!(p.shard_of(block), s, "n={nblocks} shards={shards}");
                    }
                }
                assert_eq!(covered, nblocks);
            }
        }
    }

    #[test]
    fn balanced_never_exceeds_the_uniform_weighted_imbalance() {
        let mut prng = Prng::new(0xBA2);
        for nblocks in [8u64, 81, 100, 729] {
            for shards in [2u32, 3, 4, 8] {
                // skewed weights: a hot prefix plus random noise
                let weights: Vec<u64> = (0..nblocks)
                    .map(|b| if b < nblocks / 4 { 50 + prng.below(50) } else { prng.below(5) })
                    .collect();
                let uniform = ShardPartition::new(nblocks, shards);
                let balanced = ShardPartition::balanced(nblocks, shards, &weights);
                let ub = uniform.weighted_imbalance(&weights);
                let bb = balanced.weighted_imbalance(&weights);
                assert!(
                    bb <= ub + 1e-12,
                    "n={nblocks} shards={shards}: balanced {bb} > uniform {ub}"
                );
            }
        }
    }

    #[test]
    fn balanced_with_zero_weights_falls_back_to_uniform() {
        let weights = vec![0u64; 10];
        let p = ShardPartition::balanced(10, 4, &weights);
        assert_eq!(p, ShardPartition::new(10, 4));
        assert!((p.weighted_imbalance(&weights) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn balanced_splits_a_hot_block_domain_evenly() {
        // all weight in two hot blocks far apart: the optimal 2-cut
        // isolates them on different shards
        let mut weights = vec![0u64; 10];
        weights[0] = 100;
        weights[9] = 100;
        let p = ShardPartition::balanced(10, 2, &weights);
        assert_eq!(p.shards(), 2);
        assert!((p.weighted_imbalance(&weights) - 1.0).abs() < 1e-12);
        assert_ne!(p.shard_of(0), p.shard_of(9));
    }
}
