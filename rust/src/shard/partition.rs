//! Contiguous partitioning of the block-level compact domain.
//!
//! The block engine stores the compact domain block-major: block slot
//! `b` (row-major over the coarse compact extent) owns cells
//! `[b·ρ², (b+1)·ρ²)`. A shard partition splits `[0, nblocks)` into
//! contiguous ranges of blocks, one per shard, so each shard's state
//! slice is a contiguous sub-range of the single-engine buffer — the
//! same chunking rule `util::pool` uses for tiles, lifted to ownership.
//! Contiguity is what keeps per-shard seeding, hashing, and byte
//! accounting exact: the union of the slices *is* the single-engine
//! buffer, bit for bit.

/// A static assignment of coarse blocks to shards: shard `i` owns the
/// half-open block range `range(i)`. Ranges are contiguous, disjoint,
/// cover `[0, nblocks)`, and are never empty — a request for more
/// shards than blocks is clamped, so `shards()` reports the *effective*
/// count.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPartition {
    nblocks: u64,
    chunk: u64,
    ranges: Vec<(u64, u64)>,
}

impl ShardPartition {
    /// Partition `nblocks` blocks into (at most) `shards` contiguous
    /// ranges of `ceil(nblocks / shards)` blocks each.
    pub fn new(nblocks: u64, shards: u32) -> ShardPartition {
        let want = (shards.max(1) as u64).min(nblocks.max(1));
        let chunk = nblocks.max(1).div_ceil(want);
        let mut ranges = Vec::new();
        let mut start = 0u64;
        while start < nblocks {
            let end = (start + chunk).min(nblocks);
            ranges.push((start, end));
            start = end;
        }
        if ranges.is_empty() {
            ranges.push((0, 0));
        }
        ShardPartition {
            nblocks,
            chunk,
            ranges,
        }
    }

    /// Effective number of shards.
    pub fn shards(&self) -> usize {
        self.ranges.len()
    }

    /// Half-open global block range `[start, end)` owned by shard `s`.
    pub fn range(&self, s: usize) -> (u64, u64) {
        self.ranges[s]
    }

    /// Total blocks across all shards.
    pub fn nblocks(&self) -> u64 {
        self.nblocks
    }

    /// Owning shard of a global block index.
    #[inline]
    pub fn shard_of(&self, block: u64) -> usize {
        ((block / self.chunk) as usize).min(self.ranges.len() - 1)
    }

    /// Load imbalance: largest shard's block count over the ideal
    /// `nblocks / shards` (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        if self.nblocks == 0 {
            return 1.0;
        }
        let max = self
            .ranges
            .iter()
            .map(|(a, b)| b - a)
            .max()
            .unwrap_or(0) as f64;
        max / (self.nblocks as f64 / self.ranges.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_cover_disjointly_and_shard_of_agrees() {
        for nblocks in [1u64, 3, 7, 81, 100, 6561] {
            for shards in [1u32, 2, 3, 4, 8, 200] {
                let p = ShardPartition::new(nblocks, shards);
                assert!(p.shards() as u64 <= nblocks.max(1));
                let mut covered = 0u64;
                for s in 0..p.shards() {
                    let (a, b) = p.range(s);
                    assert!(a < b, "empty shard {s} for n={nblocks} shards={shards}");
                    assert_eq!(a, covered, "gap before shard {s}");
                    covered = b;
                    for block in a..b {
                        assert_eq!(p.shard_of(block), s);
                    }
                }
                assert_eq!(covered, nblocks);
            }
        }
    }

    #[test]
    fn clamps_to_block_count() {
        let p = ShardPartition::new(3, 16);
        assert_eq!(p.shards(), 3);
        assert!((p.imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_reflects_ragged_tail() {
        // 10 blocks over 4 shards: chunks of 3,3,3,1 -> max 3 vs mean 2.5
        let p = ShardPartition::new(10, 4);
        assert_eq!(p.shards(), 4);
        assert!((p.imbalance() - 1.2).abs() < 1e-12);
        // exact split is perfectly balanced
        let q = ShardPartition::new(8, 4);
        assert!((q.imbalance() - 1.0).abs() < 1e-12);
    }
}
