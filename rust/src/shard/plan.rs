//! Static halo-exchange planning.
//!
//! The block engine's [`BlockMaps`] adjacency table already answers the
//! only topology question decomposition needs: which ≤ 8 Moore neighbor
//! blocks does each block read? Projecting that table through a
//! [`ShardPartition`] yields, per shard, (a) the set of *remote* blocks
//! its boundary reads — the ghost ring — and (b) a remapped neighbor
//! table whose entries point into the shard's combined
//! `local ++ ghost` buffer instead of the global one. Routes are
//! derived once, before step 0; the per-step exchange is pure gather →
//! scatter along them, with no map evaluations and no topology queries.
//!
//! Two refinements ride on the same projection (DESIGN.md §5d):
//!
//! - **Rim compaction**: each route records the Moore-direction mask its
//!   destination actually reads the ghost tile from, so the exchange can
//!   ship only the consumed rows/columns/corners
//!   ([`crate::ca::backend::RimSegs`]) instead of whole tiles — the
//!   block-level analogue of the paper's "move only what neighborhood
//!   access requires".
//! - **Interior/boundary split**: per shard, local blocks whose remapped
//!   neighbors all stay local ([`HaloPlan::interior`]) can sweep
//!   concurrently with the exchange; only the [`HaloPlan::boundary`]
//!   blocks read ghosts and must wait for it.

use std::collections::HashMap;

use super::partition::ShardPartition;
use crate::ca::backend::RimSegs;
use crate::maps::cache::{BlockMaps, NO_BLOCK};

/// One halo copy: the rim of local block `src_block` of shard
/// `src_shard` is copied into ghost slot `ghost_slot` of `dst_shard`'s
/// ghost ring (every step, after the previous step's barrier).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HaloRoute {
    pub src_shard: usize,
    /// Block index local to the source shard (global − range start).
    pub src_block: u64,
    pub dst_shard: usize,
    /// Ghost-ring slot in the destination shard.
    pub ghost_slot: u64,
    /// Moore-direction consumption mask: bit `m` set ⇔ some
    /// `dst_shard`-local block reads this ghost tile as its `MOORE[m]`
    /// neighbor. Determines the rim the route must ship.
    pub dirs: u8,
}

impl HaloRoute {
    /// The rim this route ships under compaction.
    pub fn rim(&self, rho: u32) -> RimSegs {
        RimSegs::from_dirs(rho, self.dirs)
    }
}

/// The complete exchange plan for one `(BlockMaps, ShardPartition)`.
#[derive(Clone, Debug)]
pub struct HaloPlan {
    /// All cross-shard tile copies, destination-major, ghost slots in
    /// ascending first-encounter order — fully deterministic.
    pub routes: Vec<HaloRoute>,
    /// Ghost-ring size (in blocks) per shard.
    pub ghost_counts: Vec<u64>,
    /// Per shard, per *local* block: the 8 Moore neighbor base slots in
    /// the shard's combined `local ++ ghost` buffer ([`NO_BLOCK`] =
    /// absent neighbor, exactly as in the global table).
    pub neighbors: Vec<Vec<[u64; 8]>>,
    /// Per shard: local block indices none of whose neighbors are
    /// ghosts — safe to sweep while the exchange runs.
    pub interior: Vec<Vec<u64>>,
    /// Per shard: local block indices with ≥ 1 ghost neighbor — swept
    /// after the exchange barrier.
    pub boundary: Vec<Vec<u64>>,
    /// Block side ρ (tile is ρ² cells).
    pub rho: u32,
}

impl HaloPlan {
    /// Derive the plan from the cached global adjacency. Pure
    /// projection: no λ/ν evaluations happen here.
    pub fn build(maps: &BlockMaps, part: &ShardPartition) -> HaloPlan {
        let rho = maps.block.rho;
        let tile = rho as u64 * rho as u64;
        let mut routes = Vec::new();
        let mut ghost_counts = Vec::with_capacity(part.shards());
        let mut neighbors = Vec::with_capacity(part.shards());
        let mut interior = Vec::with_capacity(part.shards());
        let mut boundary = Vec::with_capacity(part.shards());
        for s in 0..part.shards() {
            let (start, end) = part.range(s);
            let nlocal = end - start;
            // ghost slots in first-encounter order (blocks ascending,
            // Moore directions in order) — deterministic. Each entry
            // also accumulates the direction mask its readers consume.
            let mut ghost_of: HashMap<u64, (u64, u8)> = HashMap::new();
            let mut local_tables = Vec::with_capacity(nlocal as usize);
            let mut inner = Vec::new();
            let mut rim = Vec::new();
            for b in start..end {
                let global = maps.neighbors_of(b);
                let mut slots = [NO_BLOCK; 8];
                let mut touches_ghost = false;
                for (m, &base) in global.iter().enumerate() {
                    if base == NO_BLOCK {
                        continue;
                    }
                    let nb = base / tile;
                    slots[m] = if (start..end).contains(&nb) {
                        (nb - start) * tile
                    } else {
                        touches_ghost = true;
                        let next = ghost_of.len() as u64;
                        let entry = ghost_of.entry(nb).or_insert((next, 0));
                        entry.1 |= 1 << m;
                        (nlocal + entry.0) * tile
                    };
                }
                if touches_ghost {
                    rim.push(b - start);
                } else {
                    inner.push(b - start);
                }
                local_tables.push(slots);
            }
            let mut ghosts: Vec<(u64, (u64, u8))> = ghost_of.into_iter().collect();
            ghosts.sort_by_key(|&(_, (slot, _))| slot);
            ghost_counts.push(ghosts.len() as u64);
            for (block, (slot, dirs)) in ghosts {
                let src = part.shard_of(block);
                routes.push(HaloRoute {
                    src_shard: src,
                    src_block: block - part.range(src).0,
                    dst_shard: s,
                    ghost_slot: slot,
                    dirs,
                });
            }
            neighbors.push(local_tables);
            interior.push(inner);
            boundary.push(rim);
        }
        HaloPlan {
            routes,
            ghost_counts,
            neighbors,
            interior,
            boundary,
            rho,
        }
    }

    /// Bytes copied across shard boundaries per step when shipping whole
    /// tiles (1-byte cells) — the pre-compaction traffic model.
    pub fn halo_bytes_per_step(&self) -> u64 {
        self.routes.len() as u64 * self.rho as u64 * self.rho as u64
    }

    /// Cells the compacted exchange ships per step (sum of the routes'
    /// rim sizes) — multiply by the backend's unit accounting for exact
    /// bytes.
    pub fn compacted_cells_per_step(&self) -> u64 {
        self.routes
            .iter()
            .map(|r| r.rim(self.rho).cell_count())
            .sum()
    }

    /// Bytes held by the remapped per-shard neighbor tables.
    pub fn table_bytes(&self) -> u64 {
        self.neighbors
            .iter()
            .map(|t| (t.len() * std::mem::size_of::<[u64; 8]>()) as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fractal::catalog;

    fn plan_for(shards: u32) -> (BlockMaps, ShardPartition, HaloPlan) {
        let spec = catalog::sierpinski_triangle();
        let maps = BlockMaps::build(&spec, 5, 2, None, 2).unwrap();
        let part = ShardPartition::new(maps.block.blocks(), shards);
        let plan = HaloPlan::build(&maps, &part);
        (maps, part, plan)
    }

    #[test]
    fn single_shard_has_no_halo_and_identity_tables() {
        let (maps, part, plan) = plan_for(1);
        assert_eq!(part.shards(), 1);
        assert!(plan.routes.is_empty());
        assert_eq!(plan.ghost_counts, vec![0]);
        assert_eq!(plan.halo_bytes_per_step(), 0);
        assert_eq!(plan.compacted_cells_per_step(), 0);
        // every block is interior when nothing is remote
        assert_eq!(plan.interior[0].len() as u64, maps.block.blocks());
        assert!(plan.boundary[0].is_empty());
        // remapped table == global table when one shard owns everything
        for b in 0..maps.block.blocks() {
            assert_eq!(&plan.neighbors[0][b as usize], maps.neighbors_of(b));
        }
    }

    #[test]
    fn routes_are_consistent_with_the_global_adjacency() {
        let (maps, part, plan) = plan_for(4);
        let tile = maps.block.rho as u64 * maps.block.rho as u64;
        for s in 0..part.shards() {
            let (start, end) = part.range(s);
            let nlocal = end - start;
            // collect this shard's ghost slots -> source global block
            let mut ghost_src: HashMap<u64, u64> = HashMap::new();
            let mut ghost_dirs: HashMap<u64, u8> = HashMap::new();
            for r in plan.routes.iter().filter(|r| r.dst_shard == s) {
                let global = part.range(r.src_shard).0 + r.src_block;
                assert_ne!(part.shard_of(global), s, "route sources a local block");
                assert!(ghost_src.insert(r.ghost_slot, global).is_none());
                ghost_dirs.insert(r.ghost_slot, r.dirs);
                assert_ne!(r.dirs, 0, "a routed ghost must be consumed");
            }
            assert_eq!(ghost_src.len() as u64, plan.ghost_counts[s]);
            // ghost slots are contiguous from 0
            for slot in 0..plan.ghost_counts[s] {
                assert!(ghost_src.contains_key(&slot));
            }
            // every remapped entry resolves to the block the global table
            // named, and its direction is flagged in the route's mask
            let mut seen_boundary = Vec::new();
            for (lb, slots) in plan.neighbors[s].iter().enumerate() {
                let global_tbl = maps.neighbors_of(start + lb as u64);
                let mut touches = false;
                for m in 0..8 {
                    if global_tbl[m] == NO_BLOCK {
                        assert_eq!(slots[m], NO_BLOCK);
                        continue;
                    }
                    let want = global_tbl[m] / tile;
                    let got = slots[m] / tile;
                    let resolved = if got < nlocal {
                        start + got
                    } else {
                        touches = true;
                        let slot = got - nlocal;
                        assert_ne!(
                            ghost_dirs[&slot] & (1 << m),
                            0,
                            "shard {s} block {lb} dir {m} missing from rim mask"
                        );
                        ghost_src[&slot]
                    };
                    assert_eq!(resolved, want, "shard {s} block {lb} dir {m}");
                }
                if touches {
                    seen_boundary.push(lb as u64);
                }
            }
            assert_eq!(seen_boundary, plan.boundary[s], "boundary set mismatch");
            // interior + boundary partition the local blocks
            let mut all: Vec<u64> = plan.interior[s]
                .iter()
                .chain(plan.boundary[s].iter())
                .copied()
                .collect();
            all.sort_unstable();
            assert_eq!(all, (0..nlocal).collect::<Vec<u64>>());
        }
    }

    #[test]
    fn halo_traffic_scales_with_shard_count_and_compaction_undercuts_it() {
        let (_, _, p1) = plan_for(1);
        let (_, _, p2) = plan_for(2);
        let (_, _, p4) = plan_for(4);
        assert_eq!(p1.halo_bytes_per_step(), 0);
        assert!(p2.halo_bytes_per_step() > 0);
        assert!(p4.halo_bytes_per_step() >= p2.halo_bytes_per_step());
        assert!(p4.table_bytes() > 0);
        // the compacted rim never exceeds whole tiles, and at ρ=2 with
        // partially-consumed ghosts it is strictly below
        for p in [&p2, &p4] {
            let compact = p.compacted_cells_per_step();
            assert!(compact <= p.halo_bytes_per_step());
            assert!(compact > 0);
        }
        assert!(
            p4.compacted_cells_per_step() < p4.halo_bytes_per_step(),
            "compaction should drop at least one unread row/column"
        );
    }
}
