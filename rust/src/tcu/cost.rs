//! Tensor-core cost model for the three GPU generations in the paper's
//! testbed (Table 1). With no physical GPU in this environment, Figure 14's
//! "TCU on vs off" comparison is reproduced two ways:
//!
//! 1. *measured* — the simulated-MMA map path vs the scalar map path on
//!    CPU (validates the encoding, but CPU timing says nothing about TCU
//!    hardware), and
//! 2. *modeled* — this cost model: per-warp cycle counts for computing a
//!    batch of map evaluations with CUDA cores vs one WMMA op, calibrated
//!    to the published per-generation throughput ratios.
//!
//! The model intentionally stays simple (counts issued operations, applies
//! per-generation throughput and launch overheads); its purpose is the
//! *shape* of Fig. 14 — a modest constant-factor gain (paper: 1.11×–1.3×,
//! with a <1 anomaly for 32×32 blocks on Volta), not absolute times.

/// GPU generation of the paper's Table 1 setups.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Generation {
    /// Setup A: TITAN V (first-gen TCU).
    Volta,
    /// Setup B: TITAN RTX (second-gen TCU).
    Turing,
    /// Setup C: A100 (third-gen TCU).
    Ampere,
}

impl Generation {
    pub fn name(&self) -> &'static str {
        match self {
            Generation::Volta => "volta-titan-v",
            Generation::Turing => "turing-titan-rtx",
            Generation::Ampere => "ampere-a100",
        }
    }

    pub fn all() -> [Generation; 3] {
        [Generation::Volta, Generation::Turing, Generation::Ampere]
    }
}

/// Per-generation microarchitecture constants (per SM, per cycle).
///
/// Calibration: `cuda_ops_per_level` counts the scalar work one map level
/// costs on CUDA cores (integer div/mod for `θ_μ`, `H` table lookup, two
/// FMAs of the sum-of-products); `digit_ops_per_level` is the part the TCU
/// path still executes on CUDA cores (digit extraction only — the FMAs
/// move into the WMMA op). Newer generations execute the scalar path
/// relatively faster (better integer throughput and L2), which is why the
/// paper's TCU gain *shrinks* from Volta (1.3×) to Ampere (1.11×).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub generation: Generation,
    /// FP32/INT lanes per SM (CUDA-core path throughput).
    pub fma_per_cycle: f64,
    /// f16 MAC throughput of the tensor units per SM per cycle.
    pub tcu_mac_per_cycle: f64,
    /// Fixed per-WMMA-call overhead in cycles (fragment load/store, sync).
    pub wmma_overhead_cycles: f64,
    /// Extra per-launch scheduling penalty for TCU issue (first-gen quirk
    /// behind the paper's Volta 32×32 anomaly).
    pub tcu_issue_penalty: f64,
    /// Scalar ops per point per level on the pure CUDA-core path.
    pub cuda_ops_per_level: f64,
    /// Scalar ops per point per level that remain with the TCU path.
    pub digit_ops_per_level: f64,
}

impl CostModel {
    pub fn for_generation(g: Generation) -> CostModel {
        match g {
            // TITAN V: first-gen TCUs, slowest scalar path (integer
            // div/mod by k=3 is emulated, ~10+ instructions), highest
            // fragment overhead and issue penalty.
            Generation::Volta => CostModel {
                generation: g,
                fma_per_cycle: 64.0,
                tcu_mac_per_cycle: 512.0,
                wmma_overhead_cycles: 4.0,
                tcu_issue_penalty: 10.0,
                cuda_ops_per_level: 16.0,
                digit_ops_per_level: 6.0,
            },
            // TITAN RTX: second-gen TCUs, faster issue, better int path.
            Generation::Turing => CostModel {
                generation: g,
                fma_per_cycle: 64.0,
                tcu_mac_per_cycle: 512.0,
                wmma_overhead_cycles: 2.0,
                tcu_issue_penalty: 4.0,
                cuda_ops_per_level: 14.0,
                digit_ops_per_level: 6.0,
            },
            // A100: third-gen TCUs (double MAC rate), strongest scalar
            // path — which is why its *relative* TCU gain is the smallest
            // (paper: 1.11× vs Volta's 1.3×).
            Generation::Ampere => CostModel {
                generation: g,
                fma_per_cycle: 64.0,
                tcu_mac_per_cycle: 1024.0,
                wmma_overhead_cycles: 2.0,
                tcu_issue_penalty: 2.0,
                cuda_ops_per_level: 10.0,
                digit_ops_per_level: 6.0,
            },
        }
    }

    /// Cycles to evaluate `batch` map evaluations of `r` levels each on
    /// CUDA cores only.
    pub fn cuda_core_cycles(&self, batch: u64, r: u32) -> f64 {
        batch as f64 * self.cuda_ops_per_level * r as f64 / self.fma_per_cycle
    }

    /// Cycles to evaluate the same batch with WMMA: digit extraction stays
    /// on CUDA cores; each 16×16×16 fragment covers 16 evaluations and
    /// costs `4096 / MAC-throughput` plus fixed overhead.
    pub fn tcu_cycles(&self, batch: u64, r: u32) -> f64 {
        let frags = batch.div_ceil(16) as f64;
        let mma_cycles = frags * (4096.0 / self.tcu_mac_per_cycle + self.wmma_overhead_cycles);
        let digit_cycles =
            batch as f64 * self.digit_ops_per_level * r as f64 / self.fma_per_cycle;
        mma_cycles + self.tcu_issue_penalty + digit_cycles
    }

    /// Modeled TCU-on over TCU-off speedup for the map-evaluation phase of
    /// one simulation step (Fig. 14's quantity; map work is a fraction
    /// `map_frac` of total step work — gather/rule work is unchanged).
    pub fn fig14_speedup(&self, batch: u64, r: u32, map_frac: f64) -> f64 {
        let off = self.cuda_core_cycles(batch, r);
        let on = self.tcu_cycles(batch, r);
        let other = off * (1.0 - map_frac) / map_frac;
        (off + other) / (on + other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcu_wins_at_scale_on_all_generations() {
        // Paper Fig. 14 top speedups: Volta ~1.3×, Turing ~1.2×,
        // Ampere ~1.11×. The model must land in those neighbourhoods and
        // preserve the (counter-intuitive but published) ordering.
        let f = 0.6;
        let s_volta = CostModel::for_generation(Generation::Volta).fig14_speedup(1 << 20, 12, f);
        let s_turing = CostModel::for_generation(Generation::Turing).fig14_speedup(1 << 20, 12, f);
        let s_ampere = CostModel::for_generation(Generation::Ampere).fig14_speedup(1 << 20, 12, f);
        assert!((1.2..1.4).contains(&s_volta), "volta {s_volta}");
        assert!((1.15..1.3).contains(&s_turing), "turing {s_turing}");
        assert!((1.05..1.2).contains(&s_ampere), "ampere {s_ampere}");
        assert!(s_volta > s_turing && s_turing > s_ampere);
    }

    #[test]
    fn ampere_beats_volta_overhead() {
        let v = CostModel::for_generation(Generation::Volta);
        let a = CostModel::for_generation(Generation::Ampere);
        assert!(a.tcu_cycles(1 << 16, 12) < v.tcu_cycles(1 << 16, 12));
    }

    #[test]
    fn tiny_batches_can_lose() {
        // Fixed WMMA/issue overhead dominates for a near-empty fragment —
        // the Volta 32×32 anomaly direction (paper: S ≈ 0.75×).
        let m = CostModel::for_generation(Generation::Volta);
        let s = m.fig14_speedup(4, 12, 0.9);
        assert!(s < 1.0, "s={s}");
    }

    #[test]
    fn speedup_increases_with_map_fraction() {
        let m = CostModel::for_generation(Generation::Ampere);
        let lo = m.fig14_speedup(1 << 20, 12, 0.2);
        let hi = m.fig14_speedup(1 << 20, 12, 0.8);
        assert!(hi > lo);
    }
}
