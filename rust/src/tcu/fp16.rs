//! Software IEEE-754 binary16 (half precision).
//!
//! The paper's tensor-core path multiplies FP16 operands and accumulates in
//! FP32 (CUDA WMMA `16×16×16 f16·f16+f32`). There is no `half` crate
//! offline, so we implement the conversions: round-to-nearest-even
//! `f32 → f16`, exact `f16 → f32`. The simulator uses these to reproduce
//! the paper's numeric behaviour — including the exactness cliff at
//! integers > 2048 that bounds the fractal level usable at thread level
//! (DESIGN.md §Hardware-Adaptation).

/// Convert `f32` to binary16 bits with round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // unbias (f32 bias 127 -> f16 bias 15)
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // normal range
        let half_exp = (unbiased + 15) as u16;
        let mant10 = (mant >> 13) as u16;
        let round_bits = mant & 0x1FFF;
        let mut out = sign | (half_exp << 10) | mant10;
        // round to nearest even on the 13 dropped bits
        if round_bits > 0x1000 || (round_bits == 0x1000 && (mant10 & 1) == 1) {
            out = out.wrapping_add(1); // may carry into exponent: correct behaviour
        }
        out
    } else if unbiased >= -25 {
        // subnormal half: value = mant_half · 2^-24, with
        // x = full · 2^(unbiased-23)  ⇒  mant_half = full >> (-unbiased-1)
        let shift = (-unbiased - 1) as u32;
        let full = mant | 0x0080_0000; // implicit leading 1
        let mant_half = (full >> shift) as u16;
        let rem = full & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let mut out = sign | mant_half;
        if rem > halfway || (rem == halfway && (mant_half & 1) == 1) {
            out = out.wrapping_add(1);
        }
        out
    } else {
        sign // underflow to zero
    }
}

/// Convert binary16 bits to `f32` (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13) // inf/nan
    } else if exp == 0 {
        if mant == 0 {
            sign // zero
        } else {
            // subnormal: normalize
            let mut e = -1i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03FF;
            // value = (mant/1024)·2^-14, normalized to 1.m × 2^(114+e-127)
            sign | (((114 + e) as u32) << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round an `f32` through f16 precision (the operand quantization tensor
/// cores apply before multiplying).
#[inline]
pub fn quantize_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Is `x` an integer exactly representable in binary16?
/// (all |x| ≤ 2048; above that only multiples of increasing powers of 2).
pub fn f16_exact_int(x: f64) -> bool {
    if x == 0.0 {
        return true;
    }
    let q = quantize_f16(x as f32) as f64;
    q == x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_small_integers() {
        for i in -2048..=2048 {
            let x = i as f32;
            assert_eq!(quantize_f16(x), x, "i={i}");
        }
    }

    #[test]
    fn exactness_cliff_at_2048() {
        assert!(f16_exact_int(2048.0));
        assert!(!f16_exact_int(2049.0));
        assert!(f16_exact_int(2050.0)); // multiple of 2 in [2048, 4096)
        // the Sierpinski thread-level r=16 Δ value from DESIGN.md:
        assert!(!f16_exact_int(2187.0)); // 3^7 — NOT exact: fp16 limit
        assert!(f16_exact_int(243.0)); // 3^5 — block-level ρ=16 is fine
        // powers of two stay exact far beyond 2048 (λ's s^{μ-1} factors)
        assert!(f16_exact_int(32768.0)); // 2^15
    }

    #[test]
    fn special_values() {
        assert_eq!(quantize_f16(0.0), 0.0);
        assert_eq!(quantize_f16(-0.0).to_bits(), (-0.0f32).to_bits());
        assert_eq!(quantize_f16(f32::INFINITY), f32::INFINITY);
        assert_eq!(quantize_f16(-f32::INFINITY), f32::NEG_INFINITY);
        assert!(quantize_f16(f32::NAN).is_nan());
        // overflow
        assert_eq!(quantize_f16(1e6), f32::INFINITY);
    }

    #[test]
    fn subnormals_roundtrip() {
        let tiny = (2.0f32).powi(-24); // smallest positive f16 subnormal
        assert_eq!(quantize_f16(tiny), tiny);
        // largest subnormal (1023 · 2^-24)
        let big_sub = 1023.0 * (2.0f32).powi(-24);
        assert_eq!(quantize_f16(big_sub), big_sub);
        // exactly half the smallest subnormal ties-to-even down to 0
        assert_eq!(quantize_f16((2.0f32).powi(-25)), 0.0);
        // 1.5 × 2^-25 rounds up to the smallest subnormal
        assert_eq!(quantize_f16(1.5 * (2.0f32).powi(-25)), tiny);
        // far below -> 0
        assert_eq!(quantize_f16(1e-9), 0.0);
    }

    #[test]
    fn rounding_is_to_nearest_even() {
        // 2049 sits exactly between 2048 and 2050; even mantissa -> 2048
        assert_eq!(quantize_f16(2049.0), 2048.0);
        // 2051 between 2050 and 2052 -> 2052 (even)
        assert_eq!(quantize_f16(2051.0), 2052.0);
    }

    #[test]
    fn fractions() {
        assert_eq!(quantize_f16(0.5), 0.5);
        assert_eq!(quantize_f16(0.25), 0.25);
        let x = 0.1f32; // inexact in f16
        assert!((quantize_f16(x) - x).abs() < 1e-3);
        assert_ne!(quantize_f16(x), x);
    }
}
