//! 16×16×16 matrix-multiply-accumulate fragments — the software analogue
//! of one CUDA WMMA tensor-core operation (`D = A × B + C`, paper Eq. 14).
//!
//! `MmaMode` selects operand precision:
//! - `Fp16` — paper-faithful: operands quantized to binary16 before the
//!   multiply, products/accumulation in f32 (Volta/Turing/Ampere WMMA).
//! - `F32` — exact f32 operands; models the TPU path where the MXU takes
//!   bf16/f32 inputs wide enough for these integer ranges.

use super::fp16::quantize_f16;

/// Fragment side (CUDA WMMA 16×16×16, paper §3.6).
pub const FRAG: usize = 16;

/// A 16×16 matrix fragment, row-major f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Fragment {
    pub data: [f32; FRAG * FRAG],
}

impl Default for Fragment {
    fn default() -> Self {
        Fragment::zero()
    }
}

impl Fragment {
    pub fn zero() -> Fragment {
        Fragment {
            data: [0.0; FRAG * FRAG],
        }
    }

    #[inline]
    pub fn get(&self, row: usize, col: usize) -> f32 {
        self.data[row * FRAG + col]
    }

    #[inline]
    pub fn set(&mut self, row: usize, col: usize, v: f32) {
        self.data[row * FRAG + col] = v;
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(f: impl Fn(usize, usize) -> f32) -> Fragment {
        let mut m = Fragment::zero();
        for r in 0..FRAG {
            for c in 0..FRAG {
                m.set(r, c, f(r, c));
            }
        }
        m
    }
}

/// Operand precision mode for the simulated tensor core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MmaMode {
    /// FP16 operands, FP32 accumulate (the paper's configuration).
    Fp16,
    /// F32 operands (exact; TPU MXU analogue for this integer range).
    F32,
}

/// One warp-level MMA: `D = A × B + C`.
///
/// In `Fp16` mode each operand element is first rounded through binary16 —
/// exactly what loading a WMMA fragment from f16 storage does on real
/// hardware. Products and accumulation stay in f32, matching the
/// FP16×FP16+FP32 configuration the paper selected for correctness.
pub fn mma(a: &Fragment, b: &Fragment, c: &Fragment, mode: MmaMode) -> Fragment {
    let mut d = Fragment::zero();
    let quant = |x: f32| match mode {
        MmaMode::Fp16 => quantize_f16(x),
        MmaMode::F32 => x,
    };
    for i in 0..FRAG {
        for j in 0..FRAG {
            let mut acc = c.get(i, j);
            for p in 0..FRAG {
                acc += quant(a.get(i, p)) * quant(b.get(p, j));
            }
            d.set(i, j, acc);
        }
    }
    d
}

/// Rectangular matmul `A (m×k) · B (k×n)` decomposed into 16×16×16
/// fragment MMAs — how a kernel drives WMMA over matrices that are not
/// fragment-shaped: every operand tile is gathered zero-padded into a
/// [`Fragment`], accumulated along the k blocks with [`mma`], and the
/// result block scattered back. Row-major slices, `a.len() = m·k`,
/// `b.len() = k·n`, result `m·n`.
pub fn mma_rect(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, mode: MmaMode) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    let blocks = |d: usize| d.div_ceil(FRAG);
    for bi in 0..blocks(m) {
        for bj in 0..blocks(n) {
            let mut c = Fragment::zero();
            for bk in 0..blocks(k) {
                let afrag = Fragment::from_fn(|r, p| {
                    let (row, col) = (bi * FRAG + r, bk * FRAG + p);
                    if row < m && col < k {
                        a[row * k + col]
                    } else {
                        0.0
                    }
                });
                let bfrag = Fragment::from_fn(|p, cj| {
                    let (row, col) = (bk * FRAG + p, bj * FRAG + cj);
                    if row < k && col < n {
                        b[row * n + col]
                    } else {
                        0.0
                    }
                });
                c = mma(&afrag, &bfrag, &c, mode);
            }
            for r in 0..FRAG {
                for cj in 0..FRAG {
                    let (row, col) = (bi * FRAG + r, bj * FRAG + cj);
                    if row < m && col < n {
                        out[row * n + col] = c.get(r, cj);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_times_b_is_b() {
        let ident = Fragment::from_fn(|r, c| if r == c { 1.0 } else { 0.0 });
        let b = Fragment::from_fn(|r, c| (r * 16 + c) as f32 % 97.0);
        let d = mma(&ident, &b, &Fragment::zero(), MmaMode::F32);
        assert_eq!(d, b);
    }

    #[test]
    fn accumulator_is_added() {
        let zero = Fragment::zero();
        let c = Fragment::from_fn(|r, _| r as f32);
        let d = mma(&zero, &zero, &c, MmaMode::Fp16);
        assert_eq!(d, c);
    }

    #[test]
    fn fp16_mode_quantizes_operands() {
        // 2187 (3^7) is not f16-exact; 2048 is. A 1×1 effective product.
        let mut a = Fragment::zero();
        let mut b = Fragment::zero();
        a.set(0, 0, 2187.0);
        b.set(0, 0, 1.0);
        let d16 = mma(&a, &b, &Fragment::zero(), MmaMode::Fp16);
        let d32 = mma(&a, &b, &Fragment::zero(), MmaMode::F32);
        assert_eq!(d32.get(0, 0), 2187.0);
        assert_ne!(d16.get(0, 0), 2187.0, "fp16 must round 3^7");
    }

    #[test]
    fn small_integer_mma_is_exact_in_fp16() {
        // All operands ≤ 2048 → every product and sum is exact.
        let a = Fragment::from_fn(|r, c| ((r * 7 + c * 3) % 100) as f32);
        let b = Fragment::from_fn(|r, c| ((r * 5 + c * 11) % 100) as f32);
        let d16 = mma(&a, &b, &Fragment::zero(), MmaMode::Fp16);
        let d32 = mma(&a, &b, &Fragment::zero(), MmaMode::F32);
        assert_eq!(d16, d32);
    }

    #[test]
    fn mma_rect_matches_naive_on_awkward_shapes() {
        // shapes straddling fragment boundaries, incl. the rule-lift's
        // ρ×(ρ+2) banded operands at ρ=16
        for (m, k, n) in [(1usize, 1usize, 1usize), (16, 18, 16), (17, 3, 20), (5, 40, 7)] {
            let a: Vec<f32> = (0..m * k).map(|i| ((i * 7) % 5) as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|i| ((i * 3) % 4) as f32).collect();
            let got = mma_rect(&a, m, k, &b, n, MmaMode::Fp16);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..k).map(|p| a[i * k + p] * b[p * n + j]).sum();
                    assert_eq!(got[i * n + j], want, "m={m} k={k} n={n} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn matches_naive_matmul() {
        let a = Fragment::from_fn(|r, c| (r as f32) - (c as f32) * 0.5);
        let b = Fragment::from_fn(|r, c| (c as f32) * 0.25 + r as f32);
        let d = mma(&a, &b, &Fragment::zero(), MmaMode::F32);
        for i in 0..FRAG {
            for j in 0..FRAG {
                let mut want = 0.0f32;
                for p in 0..FRAG {
                    want += a.get(i, p) * b.get(p, j);
                }
                assert!((d.get(i, j) - want).abs() < 1e-3);
            }
        }
    }
}
