//! Software tensor-core unit (TCU) simulator.
//!
//! The substitution for real WMMA hardware (DESIGN.md §2): binary16
//! arithmetic ([`fp16`]), 16×16 fragment MMA with selectable operand
//! precision ([`mma`]), and a per-generation cycle cost model ([`cost`])
//! used to reproduce the *shape* of the paper's Figure 14.

pub mod cost;
pub mod fp16;
pub mod mma;
pub mod rulemma;

pub use cost::{CostModel, Generation};
pub use mma::{mma, mma_rect, Fragment, MmaMode, FRAG};
