//! Rule application on the MMA seam — the paper's tensor-core thesis
//! applied to the CA transition itself, not just the λ/ν maps.
//!
//! A Moore neighbor count is a 3×3 box filter, and a box filter is
//! separable: with `E` the (ρ+2)×(ρ+2) extended occupancy of a tile
//! (centre cells plus the one-cell halo ring read from the Moore
//! adjacency, `NO_BLOCK` ⇒ 0) and `Bv`/`Bh` the banded ones matrices
//!
//! ```text
//!   Bv (ρ×(ρ+2)):  Bv[i][j] = 1  iff  j ∈ {i, i+1, i+2}
//!   Bh ((ρ+2)×ρ):  Bh[j][x] = 1  iff  j ∈ {x, x+1, x+2}
//! ```
//!
//! the product `C = Bv · E · Bh` is the ρ×ρ matrix of 3×3 window sums,
//! so `count(i,x) = C[i][x] − E[i+1][x+1]`. Both multiplies run through
//! [`crate::tcu::mma::mma_rect`], i.e. as 16×16×16 WMMA fragment ops in
//! the paper's FP16×FP16+FP32 configuration — exact here because every
//! operand is 0/1 and every partial sum ≤ ρ+2 stays far inside the
//! binary16 integer range. The counts then drive `Rule::next_u8` per
//! cell and the result is repacked to words under the hole mask, which
//! keeps this path bit-identical to the carry-save word pipeline (the
//! differential matrix enforces it).
//!
//! This is a fidelity/measurement path, not a fast path on the CPU
//! simulator: its value is showing the adder formulation maps onto
//! integer fragment ops (DESIGN.md §5i) with the exact same observable
//! behavior as the bit kernels.

use crate::ca::backend::UnitPtr;
use crate::ca::bitkernel::{PackedGeom, WORD_BITS};
use crate::ca::rule::Rule;
use crate::maps::cache::NO_BLOCK;
use crate::tcu::mma::{mma_rect, MmaMode};

/// Transition one block's `ρ×ρ` tile through the MMA count pipeline:
/// drop-in for `bitkernel::sweep_block_packed` (same contract — `nb` in
/// cell-slot units, output tile at word base `base_words` through
/// `out`).
pub(crate) fn sweep_block_mma(
    cur: &[u64],
    out: UnitPtr<u64>,
    geom: &PackedGeom,
    nb: &[u64; 8],
    base_words: u64,
    rule: Rule,
) {
    let rho = geom.rho as usize;
    let wpr = geom.wpr as usize;
    let ext = rho + 2;
    let tile_cells = geom.rho as u64 * geom.rho as u64;
    // cell-base adjacency -> word-base adjacency (MOORE order:
    // NW N NE W E SW S SE)
    let mut nbw = [None; 8];
    for (m, &base) in nb.iter().enumerate() {
        if base != NO_BLOCK {
            nbw[m] = Some(base / tile_cells * geom.words_per_tile);
        }
    }
    let bit = |tile_base: u64, ix: usize, iy: usize| -> f32 {
        let w = tile_base + (iy * wpr + ix / WORD_BITS as usize) as u64;
        ((cur[w as usize] >> (ix as u32 % WORD_BITS)) & 1) as f32
    };
    let nbit = |tile: Option<u64>, ix: usize, iy: usize| -> f32 {
        match tile {
            Some(b) => bit(b, ix, iy),
            None => 0.0,
        }
    };
    // extended occupancy E: centre tile framed by the Moore halo ring
    let mut e = vec![0.0f32; ext * ext];
    for iy in 0..rho {
        for ix in 0..rho {
            e[(iy + 1) * ext + (ix + 1)] = bit(base_words, ix, iy);
        }
    }
    let hi = rho - 1;
    for ix in 0..rho {
        e[ix + 1] = nbit(nbw[1], ix, hi); // N bottom row
        e[(ext - 1) * ext + ix + 1] = nbit(nbw[6], ix, 0); // S top row
    }
    for iy in 0..rho {
        e[(iy + 1) * ext] = nbit(nbw[3], hi, iy); // W east column
        e[(iy + 1) * ext + (ext - 1)] = nbit(nbw[4], 0, iy); // E west column
    }
    e[0] = nbit(nbw[0], hi, hi); // NW
    e[ext - 1] = nbit(nbw[2], 0, hi); // NE
    e[(ext - 1) * ext] = nbit(nbw[5], hi, 0); // SW
    e[(ext - 1) * ext + (ext - 1)] = nbit(nbw[7], 0, 0); // SE
    // banded ones operands of the separable 3×3 box filter
    let bv: Vec<f32> = (0..rho * ext)
        .map(|i| {
            let (row, col) = (i / ext, i % ext);
            if col >= row && col <= row + 2 {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    let bh: Vec<f32> = (0..ext * rho)
        .map(|i| {
            let (row, col) = (i / rho, i % rho);
            if row >= col && row <= col + 2 {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    // C = (Bv · E) · Bh, both products as fragment MMAs
    let t1 = mma_rect(&bv, rho, ext, &e, ext, MmaMode::Fp16);
    let c = mma_rect(&t1, rho, ext, &bh, rho, MmaMode::Fp16);
    // counts -> rule -> repack under the hole mask
    for iy in 0..rho {
        for wx in 0..wpr {
            let mut next = 0u64;
            let lanes = (rho - wx * WORD_BITS as usize).min(WORD_BITS as usize);
            for lane in 0..lanes {
                let ix = wx * WORD_BITS as usize + lane;
                let alive = e[(iy + 1) * ext + (ix + 1)];
                let count = (c[iy * rho + ix] - alive).round() as u32;
                if rule.next_u8(alive as u8, count) != 0 {
                    next |= 1u64 << lane;
                }
            }
            next &= geom.mask_rows[iy * wpr + wx];
            unsafe { out.0.add((base_words + (iy * wpr + wx) as u64) as usize).write(next) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ca::bitkernel::sweep_block_packed;
    use crate::fractal::catalog;
    use crate::maps::block::BlockCtx;
    use crate::util::prng::Prng;

    /// The MMA count pipeline must reproduce the carry-save word
    /// pipeline word-for-word on an isolated tile — including ragged
    /// rows (ρ = 81) where the repack loop handles partial last words.
    fn mma_matches_bitkernel(block: &BlockCtx, seed: u64) {
        let geom = PackedGeom::new(block);
        let rho = block.rho;
        let words = geom.words_per_tile as usize;
        let mut prng = Prng::new(seed);
        let mut cur = vec![0u64; words];
        for iy in 0..rho {
            for ix in 0..rho {
                if block.intra_on_fractal(ix, iy) && prng.below(100) < 45 {
                    cur[(iy * geom.wpr + ix / WORD_BITS) as usize] |= 1u64 << (ix % WORD_BITS);
                }
            }
        }
        let nb = [NO_BLOCK; 8];
        for rule_text in ["B3/S23", "B36/S23", "B2/S"] {
            let rule = Rule::parse(rule_text).unwrap();
            let mut scalar = vec![0u64; words];
            let mut lifted = vec![0u64; words];
            sweep_block_packed(&cur, UnitPtr(scalar.as_mut_ptr()), &geom, &nb, 0, rule);
            sweep_block_mma(&cur, UnitPtr(lifted.as_mut_ptr()), &geom, &nb, 0, rule);
            assert_eq!(scalar, lifted, "rho={rho} rule={rule_text}");
        }
    }

    #[test]
    fn mma_rule_lift_matches_word_pipeline_on_isolated_tiles() {
        let tri = catalog::sierpinski_triangle();
        mma_matches_bitkernel(&BlockCtx::new(&tri, 6, 16).unwrap(), 0x3A);
        let vic = catalog::vicsek();
        mma_matches_bitkernel(&BlockCtx::new(&vic, 3, 27).unwrap(), 0x3B);
    }

    #[test]
    fn mma_rule_lift_handles_ragged_rows() {
        // ρ = 81: one full word plus a 17-bit tail per row
        let vic = catalog::vicsek();
        mma_matches_bitkernel(&BlockCtx::new(&vic, 4, 81).unwrap(), 0x3C);
    }

    /// Neighbor tiles must flow through the halo ring of E: two
    /// horizontally adjacent tiles, the east tile's west column feeding
    /// the west tile's counts, checked against the word pipeline.
    #[test]
    fn mma_rule_lift_reads_the_moore_halo() {
        let tri = catalog::sierpinski_triangle();
        let block = BlockCtx::new(&tri, 6, 16).unwrap();
        let geom = PackedGeom::new(&block);
        let words = geom.words_per_tile as usize;
        let tile_cells = geom.rho as u64 * geom.rho as u64;
        let mut prng = Prng::new(0x3D);
        // two tiles: word bases 0 and words_per_tile, cell bases 0 and ρ²
        let mut cur = vec![0u64; 2 * words];
        for tile in 0..2u64 {
            for iy in 0..block.rho {
                for ix in 0..block.rho {
                    if block.intra_on_fractal(ix, iy) && prng.below(100) < 45 {
                        let w = tile * geom.words_per_tile
                            + (iy * geom.wpr + ix / WORD_BITS) as u64;
                        cur[w as usize] |= 1u64 << (ix % WORD_BITS);
                    }
                }
            }
        }
        let rule = Rule::parse("B3/S23").unwrap();
        // west tile sees the east tile as its E neighbor (MOORE slot 4)
        let mut nb = [NO_BLOCK; 8];
        nb[4] = tile_cells;
        let mut scalar = vec![0u64; 2 * words];
        let mut lifted = vec![0u64; 2 * words];
        sweep_block_packed(&cur, UnitPtr(scalar.as_mut_ptr()), &geom, &nb, 0, rule);
        sweep_block_mma(&cur, UnitPtr(lifted.as_mut_ptr()), &geom, &nb, 0, rule);
        assert_eq!(&scalar[..words], &lifted[..words]);
    }
}
