//! Minimal command-line argument parser (the environment is offline, so no
//! `clap`). Supports subcommands, `--flag`, `--key value`, `--key=value`,
//! typed getters with defaults, and auto-generated usage text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declared option for usage rendering.
#[derive(Clone, Debug)]
struct Decl {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Parsed argument bag for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    decls: Vec<Decl>,
}

#[derive(Debug)]
pub enum CliError {
    MissingValue(String),
    BadValue { key: String, value: String, want: &'static str },
    Unknown(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(k) => write!(f, "option --{k} expects a value"),
            CliError::BadValue { key, value, want } => {
                write!(f, "option --{key}={value} is not a valid {want}")
            }
            CliError::Unknown(k) => write!(f, "unknown option --{k}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw argv (excluding program name). The first non-dash token is
    /// the subcommand; everything after is options/positionals.
    pub fn parse(argv: &[String]) -> Result<Args, CliError> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                a.command = Some(it.next().unwrap().clone());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    a.values
                        .insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    a.values
                        .insert(body.to_string(), it.next().unwrap().clone());
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args, CliError> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    /// Declare an option (for usage text); returns `self` for chaining.
    pub fn declare(&mut self, name: &str, help: &str, default: Option<&str>, is_flag: bool) {
        self.decls.push(Decl {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(|s| s.to_string()),
            is_flag,
        });
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: name.to_string(),
                value: v.to_string(),
                want: "u64",
            }),
        }
    }

    pub fn get_u32(&self, name: &str, default: u32) -> Result<u32, CliError> {
        Ok(self.get_u64(name, default as u64)? as u32)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: name.to_string(),
                value: v.to_string(),
                want: "f64",
            }),
        }
    }

    /// Comma-separated u32 list, e.g. `--rhos 1,2,4,8`.
    pub fn get_u32_list(&self, name: &str, default: &[u32]) -> Result<Vec<u32>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|_| CliError::BadValue {
                        key: name.to_string(),
                        value: v.to_string(),
                        want: "comma-separated u32 list",
                    })
                })
                .collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Render usage text from declared options.
    pub fn usage(&self, program: &str, about: &str) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{program} — {about}\n");
        let _ = writeln!(s, "OPTIONS:");
        for d in &self.decls {
            let head = if d.is_flag {
                format!("  --{}", d.name)
            } else {
                format!("  --{} <value>", d.name)
            };
            let def = d
                .default
                .as_ref()
                .map(|v| format!(" [default: {v}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "{head:<28} {}{def}", d.help);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(&sv(&["bench", "--r", "12", "--fast", "--rho=4", "file.txt"])).unwrap();
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.get("r"), Some("12"));
        assert_eq!(a.get("rho"), Some("4"));
        assert!(a.flag("fast"));
        assert_eq!(a.positional(), &["file.txt".to_string()]);
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(&sv(&["x", "--n", "8", "--p", "0.5", "--list", "1,2,4"])).unwrap();
        assert_eq!(a.get_u64("n", 0).unwrap(), 8);
        assert_eq!(a.get_f64("p", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_u32_list("list", &[]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.get_u64("missing", 3).unwrap(), 3);
    }

    #[test]
    fn bad_value_is_an_error() {
        let a = Args::parse(&sv(&["x", "--n", "abc"])).unwrap();
        assert!(a.get_u64("n", 0).is_err());
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse(&sv(&["run", "--verbose"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.get("verbose"), None);
    }

    #[test]
    fn usage_renders_declared() {
        let mut a = Args::default();
        a.declare("r", "fractal level", Some("8"), false);
        a.declare("fast", "skip slow parts", None, true);
        let u = a.usage("squeeze", "compact fractals");
        assert!(u.contains("--r <value>"));
        assert!(u.contains("--fast"));
        assert!(u.contains("[default: 8]"));
    }
}
