//! INI-style configuration parser for experiment/sweep definitions.
//!
//! Grammar: `[section]` headers, `key = value` pairs, `#`/`;` comments,
//! blank lines ignored. Values keep their raw string; typed accessors parse
//! on demand. Used by the coordinator to load run plans (see
//! `configs/*.ini` at the repo root).

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Config {
    /// section -> key -> value. The pre-section area is section "".
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

#[derive(Debug)]
pub enum ConfigError {
    Io(String),
    Syntax { line: usize, text: String },
    Missing { section: String, key: String },
    Bad { section: String, key: String, want: &'static str },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Io(e) => write!(f, "config io error: {e}"),
            ConfigError::Syntax { line, text } => {
                write!(f, "config syntax error at line {line}: {text:?}")
            }
            ConfigError::Missing { section, key } => {
                write!(f, "missing config key [{section}] {key}")
            }
            ConfigError::Bad { section, key, want } => {
                write!(f, "config key [{section}] {key} is not a valid {want}")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            match line.split_once('=') {
                Some((k, v)) => {
                    cfg.sections
                        .entry(section.clone())
                        .or_default()
                        .insert(k.trim().to_string(), v.trim().to_string());
                }
                None => {
                    return Err(ConfigError::Syntax {
                        line: i + 1,
                        text: raw.to_string(),
                    })
                }
            }
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Config, ConfigError> {
        let text = std::fs::read_to_string(path).map_err(|e| ConfigError::Io(e.to_string()))?;
        Config::parse(&text)
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).unwrap_or(default).to_string()
    }

    pub fn require(&self, section: &str, key: &str) -> Result<&str, ConfigError> {
        self.get(section, key).ok_or_else(|| ConfigError::Missing {
            section: section.to_string(),
            key: key.to_string(),
        })
    }

    pub fn get_u64(&self, section: &str, key: &str, default: u64) -> Result<u64, ConfigError> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ConfigError::Bad {
                section: section.to_string(),
                key: key.to_string(),
                want: "u64",
            }),
        }
    }

    pub fn get_f64(&self, section: &str, key: &str, default: f64) -> Result<f64, ConfigError> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ConfigError::Bad {
                section: section.to_string(),
                key: key.to_string(),
                want: "f64",
            }),
        }
    }

    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> Result<bool, ConfigError> {
        match self.get(section, key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(_) => Err(ConfigError::Bad {
                section: section.to_string(),
                key: key.to_string(),
                want: "bool",
            }),
        }
    }

    /// Comma-separated u32 list.
    pub fn get_u32_list(
        &self,
        section: &str,
        key: &str,
        default: &[u32],
    ) -> Result<Vec<u32>, ConfigError> {
        match self.get(section, key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|_| ConfigError::Bad {
                        section: section.to_string(),
                        key: key.to_string(),
                        want: "u32 list",
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\n# experiment plan\nglobal_key = 1\n[sweep]\nr_min = 4\nr_max = 12\nrhos = 1,2,4\nshared = true\n; comment\n[job]\nname = gol-sierpinski\n";

    #[test]
    fn parses_sections_and_keys() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("", "global_key"), Some("1"));
        assert_eq!(c.get_u64("sweep", "r_min", 0).unwrap(), 4);
        assert_eq!(c.get_u32_list("sweep", "rhos", &[]).unwrap(), vec![1, 2, 4]);
        assert!(c.get_bool("sweep", "shared", false).unwrap());
        assert_eq!(c.get("job", "name"), Some("gol-sierpinski"));
    }

    #[test]
    fn missing_and_defaults() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_u64("sweep", "nope", 7).unwrap(), 7);
        assert!(c.require("sweep", "nope").is_err());
    }

    #[test]
    fn syntax_error_reports_line() {
        let err = Config::parse("ok = 1\nbroken-line\n").unwrap_err();
        match err {
            ConfigError::Syntax { line, .. } => assert_eq!(line, 2),
            other => panic!("wrong error {other:?}"),
        }
    }

    #[test]
    fn bad_typed_value() {
        let c = Config::parse("[s]\nx = abc\n").unwrap();
        assert!(c.get_u64("s", "x", 0).is_err());
        assert!(c.get_bool("s", "x", false).is_err());
    }
}
