//! Human-readable formatting (bytes, counts, durations) and a small
//! markdown/CSV table writer used by the bench harness reports.

/// `1536 → "1.50 KiB"`, `16 * 2^30 → "16.00 GiB"`.
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// `1_234_567 → "1.23M"`.
pub fn human_count(n: u64) -> String {
    const UNITS: [&str; 5] = ["", "K", "M", "G", "T"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1000.0 && u + 1 < UNITS.len() {
        v /= 1000.0;
        u += 1;
    }
    if u == 0 {
        format!("{n}")
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

/// Seconds to an adaptive unit string.
pub fn human_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// A rectangular table that renders as markdown or CSV.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells.to_vec());
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = self
            .header
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1536), "1.50 KiB");
        assert_eq!(human_bytes(16 * (1 << 30)), "16.00 GiB");
    }

    #[test]
    fn counts() {
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(1_234_567), "1.23M");
    }

    #[test]
    fn secs() {
        assert_eq!(human_secs(2.0), "2.000 s");
        assert_eq!(human_secs(0.002), "2.000 ms");
        assert!(human_secs(2e-7).ends_with("ns"));
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "x,y".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b"));
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
