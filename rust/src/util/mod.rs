//! General-purpose substrates built in-repo (the environment is offline, so
//! `rand`, `clap`, `rayon`, `proptest` and friends are replaced by the small
//! focused modules below).

pub mod cli;
pub mod config;
pub mod fmt;
pub mod pool;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod timer;
