//! Data-parallel execution substrate.
//!
//! The paper's engines run as CUDA grids of thread-blocks; our CPU analogue
//! is a chunked fork-join over index ranges built on `std::thread::scope`
//! (no rayon offline). `parallel_for_chunks` splits `[0, n)` into
//! contiguous chunks, one logical chunk stream per worker, preserving the
//! "block of threads works on a contiguous tile" structure that the
//! block-level Squeeze engine relies on for locality.

/// Number of workers to use: `SQUEEZE_THREADS` env or available parallelism.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("SQUEEZE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `body(start, end)` over disjoint chunks of `[0, n)` on `workers`
/// threads. `body` must be safe to run concurrently on disjoint ranges.
pub fn parallel_for_chunks<F>(n: u64, workers: usize, body: F)
where
    F: Fn(u64, u64) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.max(1).min((n as usize).max(1));
    if workers == 1 {
        body(0, n);
        return;
    }
    let chunk = n.div_ceil(workers as u64);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let start = (w as u64) * chunk;
            if start >= n {
                break;
            }
            let end = (start + chunk).min(n);
            let body = &body;
            scope.spawn(move || body(start, end));
        }
    });
}

/// Map `f` over `[0, n)` in parallel, writing into `out[i]` (disjoint
/// writes, so safe). `out.len()` must equal `n`.
pub fn parallel_map_into<T, F>(out: &mut [T], workers: usize, f: F)
where
    T: Send,
    F: Fn(u64) -> T + Sync,
{
    let n = out.len() as u64;
    if n == 0 {
        return;
    }
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for_chunks(n, workers, move |start, end| {
        let p = ptr; // copy the Send wrapper into the closure
        for i in start..end {
            // SAFETY: chunks are disjoint; each index is written exactly once.
            unsafe { p.0.add(i as usize).write(f(i)) }
        }
    });
}

/// Pointer wrapper asserting cross-thread use is safe for disjoint writes.
struct SendPtr<T>(*mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Parallel sum of `f(i)` over `[0, n)`.
pub fn parallel_sum<F>(n: u64, workers: usize, f: F) -> u64
where
    F: Fn(u64) -> u64 + Sync,
{
    use std::sync::atomic::{AtomicU64, Ordering};
    let total = AtomicU64::new(0);
    parallel_for_chunks(n, workers, |start, end| {
        let mut local = 0u64;
        for i in start..end {
            local += f(i);
        }
        total.fetch_add(local, Ordering::Relaxed);
    });
    total.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn covers_every_index_once() {
        let n = 10_007u64; // prime, forces ragged chunks
        let hits = AtomicU64::new(0);
        parallel_for_chunks(n, 8, |s, e| {
            hits.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), n);
    }

    #[test]
    fn zero_and_one_workers() {
        parallel_for_chunks(0, 4, |_, _| panic!("must not run"));
        let hits = AtomicU64::new(0);
        parallel_for_chunks(5, 1, |s, e| {
            assert_eq!((s, e), (0, 5));
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn more_workers_than_items() {
        let hits = AtomicU64::new(0);
        parallel_for_chunks(3, 64, |s, e| {
            hits.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn map_into_writes_each_slot() {
        let mut out = vec![0u64; 1000];
        parallel_map_into(&mut out, 7, |i| i * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i as u64) * 2);
        }
    }

    #[test]
    fn sum_matches_closed_form() {
        let n = 100_000u64;
        let s = parallel_sum(n, 16, |i| i);
        assert_eq!(s, n * (n - 1) / 2);
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }
}
