//! Deterministic pseudo-random number generation.
//!
//! The repository is fully offline, so instead of pulling `rand` we carry a
//! small, well-understood generator: SplitMix64 for seeding and
//! xoshiro256** for the stream. Both are the reference algorithms from
//! Blackman & Vigna. Determinism matters here: every engine in `ca::` must
//! seed the *same logical fractal state* from the same seed so that
//! cross-engine agreement tests are exact.

/// SplitMix64 step; used to expand a single `u64` seed into stream state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire's multiply-shift rejection).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_wide(x, bound);
            if lo >= bound || lo >= x.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        if lo == 0 && hi == u64::MAX {
            return self.next_u64();
        }
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self, lane: u64) -> Prng {
        let mut sm = self.next_u64() ^ lane.wrapping_mul(0xA24B_AED4_963E_E407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }
}

#[inline]
fn mul_wide(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_respects_bound() {
        let mut p = Prng::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(p.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(9);
        for _ in 0..1000 {
            let x = p.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn coin_is_roughly_fair() {
        let mut p = Prng::new(11);
        let heads = (0..10_000).filter(|_| p.coin(0.5)).count();
        assert!((4500..5500).contains(&heads), "heads={heads}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Prng::new(3);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut p = Prng::new(5);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            match p.range_inclusive(3, 6) {
                3 => saw_lo = true,
                6 => saw_hi = true,
                x => assert!((3..=6).contains(&x)),
            }
        }
        assert!(saw_lo && saw_hi);
    }
}
