//! Miniature property-based testing framework (offline substitute for
//! `proptest`). Provides seeded random case generation, a fixed case
//! budget, and greedy integer shrinking: when a case fails, each integer
//! input is independently shrunk toward its minimum while the property
//! still fails, and the minimal counterexample is reported in the panic.
//!
//! Usage (`no_run`: rustdoc test binaries don't inherit the xla rpath):
//! ```no_run
//! use squeeze::util::proptest::Runner;
//! let mut r = Runner::new("add-commutes", 0xC0FFEE);
//! r.run(256, |g| {
//!     let a = g.u64(0, 1000);
//!     let b = g.u64(0, 1000);
//!     Runner::check(a + b == b + a, &format!("a={a} b={b}"))
//! });
//! ```

use super::prng::Prng;

/// Per-case value source. Records drawn integers so the runner can shrink.
pub struct Gen {
    prng: Prng,
    /// Recorded draws for this case: (lo, hi, chosen).
    trace: Vec<(u64, u64, u64)>,
    /// When replaying a shrunk case, values come from here instead.
    replay: Option<Vec<u64>>,
    cursor: usize,
}

impl Gen {
    fn new(prng: Prng) -> Gen {
        Gen {
            prng,
            trace: Vec::new(),
            replay: None,
            cursor: 0,
        }
    }

    fn replaying(values: Vec<u64>) -> Gen {
        Gen {
            prng: Prng::new(0),
            trace: Vec::new(),
            replay: Some(values),
            cursor: 0,
        }
    }

    /// Draw a uniform integer in `[lo, hi]` inclusive.
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        let v = if let Some(replay) = &self.replay {
            replay.get(self.cursor).copied().unwrap_or(lo).clamp(lo, hi)
        } else {
            self.prng.range_inclusive(lo, hi)
        };
        self.cursor += 1;
        self.trace.push((lo, hi, v));
        v
    }

    pub fn u32(&mut self, lo: u32, hi: u32) -> u32 {
        self.u64(lo as u64, hi as u64) as u32
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.u64(0, 1) == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.usize(0, xs.len() - 1)]
    }
}

/// Result of a single property evaluation.
pub type CaseResult = Result<(), String>;

/// Property runner with shrinking.
pub struct Runner {
    name: String,
    seed: u64,
}

impl Runner {
    pub fn new(name: &str, seed: u64) -> Runner {
        Runner {
            name: name.to_string(),
            seed,
        }
    }

    /// Convenience assertion for property bodies.
    pub fn check(cond: bool, detail: &str) -> CaseResult {
        if cond {
            Ok(())
        } else {
            Err(detail.to_string())
        }
    }

    /// Run `cases` random cases; panics with the minimal counterexample on
    /// failure.
    pub fn run<F>(&mut self, cases: u64, prop: F)
    where
        F: Fn(&mut Gen) -> CaseResult,
    {
        let mut root = Prng::new(self.seed);
        for case in 0..cases {
            let mut g = Gen::new(root.fork(case));
            if let Err(first_fail) = prop(&mut g) {
                let (values, final_msg) = self.shrink(&g.trace, &prop, first_fail);
                panic!(
                    "property '{}' failed (case {case}, seed {:#x})\n  minimal inputs: {:?}\n  detail: {}",
                    self.name, self.seed, values, final_msg
                );
            }
        }
    }

    /// Greedy per-coordinate shrink toward each draw's lower bound.
    fn shrink<F>(
        &self,
        trace: &[(u64, u64, u64)],
        prop: &F,
        first_msg: String,
    ) -> (Vec<u64>, String)
    where
        F: Fn(&mut Gen) -> CaseResult,
    {
        let mut values: Vec<u64> = trace.iter().map(|t| t.2).collect();
        let lows: Vec<u64> = trace.iter().map(|t| t.0).collect();
        let mut msg = first_msg;
        let mut progress = true;
        let mut rounds = 0;
        while progress && rounds < 64 {
            progress = false;
            rounds += 1;
            for i in 0..values.len() {
                loop {
                    if values[i] == lows[i] {
                        break;
                    }
                    let saved = values[i];
                    // candidate ladder: the low bound, the midpoint, then
                    // decrement — the decrement step guarantees the shrink
                    // reaches the exact boundary counterexample.
                    let mid = lows[i] + (saved - lows[i]) / 2;
                    let mut shrunk = false;
                    for candidate in [lows[i], mid, saved - 1] {
                        if candidate >= saved {
                            continue;
                        }
                        values[i] = candidate;
                        let mut g = Gen::replaying(values.clone());
                        if let Err(m) = prop(&mut g) {
                            msg = m;
                            progress = true;
                            shrunk = true;
                            break;
                        }
                        values[i] = saved;
                    }
                    if !shrunk {
                        break;
                    }
                }
            }
        }
        (values, msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Runner::new("sym", 1).run(200, |g| {
            let a = g.u64(0, 100);
            let b = g.u64(0, 100);
            Runner::check(a.max(b) == b.max(a), "max symmetric")
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        let result = std::panic::catch_unwind(|| {
            Runner::new("fails-at-10", 2).run(500, |g| {
                let x = g.u64(0, 1000);
                Runner::check(x < 10, &format!("x={x}"))
            });
        });
        let msg = match result {
            Err(p) => *p.downcast::<String>().expect("panic payload"),
            Ok(()) => panic!("property should have failed"),
        };
        // shrinker must land exactly on the boundary counterexample x=10
        assert!(msg.contains("minimal inputs: [10]"), "got: {msg}");
    }

    #[test]
    fn choose_and_bool_draw_within_domain() {
        Runner::new("choose", 3).run(100, |g| {
            let v = *g.choose(&[2u32, 4, 6]);
            let b = g.bool();
            Runner::check(v % 2 == 0 && (b || !b), "domain")
        });
    }

    #[test]
    fn replay_clamps_to_bounds() {
        let mut g = Gen::replaying(vec![500]);
        let x = g.u64(1, 10);
        assert_eq!(x, 10);
    }
}
