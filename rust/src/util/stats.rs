//! Streaming statistics (Welford) and summaries for benchmark timing.
//!
//! The paper reports "average time of 100 runs ... standard error lower
//! than 1%"; `Summary::stderr_pct` is the figure our harness checks against
//! the same threshold.

/// Online mean/variance accumulator (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn stderr(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.stddev() / (self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// A finished measurement set.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: u64,
    pub mean: f64,
    pub stddev: f64,
    pub stderr: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    /// Summarize a sample vector (consumed order-independently).
    /// Returns `None` for an empty slice — zero-sample configurations
    /// (e.g. a bench point whose every rep was skipped) degrade to a
    /// reported skip at the call site instead of a panic.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut w = Welford::new();
        for &x in samples {
            w.push(x);
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if sorted.len() % 2 == 1 {
            sorted[sorted.len() / 2]
        } else {
            0.5 * (sorted[sorted.len() / 2 - 1] + sorted[sorted.len() / 2])
        };
        Some(Summary {
            n: w.count(),
            mean: w.mean(),
            stddev: w.stddev(),
            stderr: w.stderr(),
            min: w.min(),
            max: w.max(),
            median,
        })
    }

    /// Standard error as a percentage of the mean (paper's <1% criterion).
    pub fn stderr_pct(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            100.0 * self.stderr / self.mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic set is 32/7
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn summary_median_even_odd() {
        let s = Summary::of(&[1.0, 3.0, 2.0]).unwrap();
        assert_eq!(s.median, 2.0);
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn stderr_shrinks_with_n() {
        let a = Summary::of(&[1.0, 2.0, 1.0, 2.0]).unwrap();
        let many: Vec<f64> = (0..400).map(|i| if i % 2 == 0 { 1.0 } else { 2.0 }).collect();
        let b = Summary::of(&many).unwrap();
        assert!(b.stderr < a.stderr);
    }

    #[test]
    fn single_sample_is_degenerate_but_defined() {
        let s = Summary::of(&[3.5]).unwrap();
        assert_eq!(s.mean, 3.5);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.median, 3.5);
    }

    #[test]
    fn zero_samples_summarize_to_none_not_a_panic() {
        assert!(Summary::of(&[]).is_none());
    }
}
