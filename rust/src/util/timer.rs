//! Wall-clock timing helpers used by the bench harness and the coordinator
//! metrics. All results are reported in seconds as `f64`.

use std::time::Instant;

/// A started stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds since start.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    /// Restart and return the lap time in seconds.
    pub fn lap_s(&mut self) -> f64 {
        let t = self.elapsed_s();
        self.start = Instant::now();
        t
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn time<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.elapsed_s())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_is_monotonic() {
        let t = Timer::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn time_returns_result() {
        let (v, secs) = time(|| 2 + 2);
        assert_eq!(v, 4);
        assert!(secs >= 0.0);
    }

    #[test]
    fn lap_resets() {
        let mut t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let lap = t.lap_s();
        assert!(lap > 0.0);
        assert!(t.elapsed_s() <= lap + 0.5);
    }
}
