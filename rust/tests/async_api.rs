//! Integration tests for the typed async coordinator API
//! (`coordinator::api`): concurrent in-flight jobs over the shared
//! worker budget, cancellation, streaming progress, stateful sessions,
//! snapshot/restore bit-identity across engine layouts, and the
//! differential case — N interleaved sessions through the typed API
//! hash-identical to the same work run serially through the v1 line
//! protocol.

use std::sync::Arc;

use squeeze::ca::EngineKind;
use squeeze::coordinator::service::serve;
use squeeze::coordinator::{
    Coordinator, JobSpec, JobStatus, Probe, ProbeResult, SessionSnapshot,
};

fn job(id: u64, engine: &str, r: u32, steps: u32) -> JobSpec {
    JobSpec::parse_line(
        id,
        &format!("engine={engine} r={r} steps={steps} workers=1 seed=9 density=0.4"),
    )
    .expect("valid job line")
}

/// The four engine layouts the snapshot contract must cover: byte and
/// packed backends, single and sharded.
const LAYOUTS: [&str; 4] = [
    "squeeze:4",
    "squeeze-bits:4",
    "sharded-squeeze:4:3",
    "squeeze-bits:4:3",
];

#[test]
fn sustains_two_concurrent_in_flight_jobs() {
    let coord = Coordinator::new(4);
    // long enough that both jobs overlap under any scheduling
    let a = coord.submit(job(1, "squeeze:16", 8, 200_000));
    let b = coord.submit(job(2, "squeeze:16", 8, 200_000));
    // poll until both report Running at the same instant
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    let mut seen_both = false;
    while std::time::Instant::now() < deadline {
        let both = matches!(a.poll(), JobStatus::Running(_))
            && matches!(b.poll(), JobStatus::Running(_));
        if both {
            seen_both = true;
            let snap = coord.metrics().snapshot();
            assert!(snap.jobs_inflight >= 2, "{snap:?}");
            assert!(snap.budget_in_use >= 2, "{snap:?}");
            break;
        }
        std::thread::yield_now();
    }
    assert!(seen_both, "jobs never overlapped");
    // no need to run them to completion
    a.cancel();
    b.cancel();
    coord.join_jobs();
}

#[test]
fn cancel_stops_a_job_between_steps_and_progress_streams() {
    let coord = Coordinator::new(2);
    let h = coord.submit(job(1, "squeeze:16", 8, 1_000_000));
    // wait until it made observable progress
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        if let JobStatus::Running(p) = h.poll() {
            if p.steps_done > 0 {
                assert_eq!(p.steps_total, 1_000_000);
                assert!(p.cells_per_s > 0.0, "{p:?}");
                break;
            }
        }
        assert!(std::time::Instant::now() < deadline, "no progress observed");
        std::thread::yield_now();
    }
    assert!(h.cancel());
    assert_eq!(h.wait().unwrap_err(), "cancelled");
    assert!(matches!(h.poll(), JobStatus::Cancelled));
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.cancelled, 1, "{snap:?}");
    assert!(snap.progress_steps > 0, "{snap:?}");
    coord.join_jobs();
}

#[test]
fn failed_and_unknown_jobs_surface_errors() {
    let coord = Coordinator::new(2);
    let h = coord.submit(job(1, "squeeze:3", 5, 2)); // invalid ρ
    let err = h.wait().unwrap_err();
    assert!(err.contains("rho=3"), "{err}");
    assert!(matches!(h.poll(), JobStatus::Failed(_)));
    assert!(coord.wait(99).unwrap_err().contains("unknown job"));
    assert!(coord.poll(99).is_err());
    assert!(coord.cancel(99).is_err());
    coord.join_jobs();
}

#[test]
fn snapshot_restore_step_is_hash_identical_for_every_layout() {
    let coord = Coordinator::new(4);
    for engine in LAYOUTS {
        // the uninterrupted reference: one 5-step job
        let want = coord
            .submit(job(0, engine, 5, 5))
            .wait()
            .unwrap()
            .state_hash;
        // session: 3 steps, snapshot, 2 more
        let spec = job(0, engine, 5, 0);
        let open = coord.open(spec).unwrap();
        let s3 = coord.step(open.sid, 3).unwrap();
        let snap = coord.snapshot(open.sid).unwrap();
        assert_eq!(snap.steps_done, 3);
        assert_eq!(snap.state_hash, s3.state_hash);
        let s5 = coord.step(open.sid, 2).unwrap();
        assert_eq!(s5.steps_done, 5);
        assert_eq!(s5.state_hash, want, "{engine}: session diverged from job");
        // restore the 3-step snapshot and replay the remaining 2
        let restored = coord.restore(&snap).unwrap();
        assert_eq!(restored.steps_done, 3);
        assert_eq!(restored.state_hash, s3.state_hash, "{engine}: restore changed state");
        let r5 = coord.step(restored.sid, 2).unwrap();
        assert_eq!(
            r5.state_hash, want,
            "{engine}: snapshot->restore->step diverged from uninterrupted stepping"
        );
        coord.close(open.sid).unwrap();
        coord.close(restored.sid).unwrap();
    }
    coord.join_jobs();
}

#[test]
fn snapshots_restore_across_engine_layouts() {
    // the bitmap speaks canonical compact order, so a byte snapshot
    // restores into a packed sharded engine (and keeps stepping right)
    let coord = Coordinator::new(2);
    let open = coord.open(job(0, "squeeze:4", 5, 0)).unwrap();
    coord.step(open.sid, 3).unwrap();
    let mut snap = coord.snapshot(open.sid).unwrap();
    let want = coord.step(open.sid, 2).unwrap().state_hash;
    snap.spec = job(0, "squeeze-bits:4:3", 5, 0);
    let restored = coord.restore(&snap).unwrap();
    assert!(restored.engine.contains("squeeze-bits"), "{}", restored.engine);
    assert_eq!(coord.step(restored.sid, 2).unwrap().state_hash, want);
    coord.join_jobs();
}

#[test]
fn restore_rejects_corrupt_snapshots() {
    let coord = Coordinator::new(2);
    let open = coord.open(job(0, "squeeze:4", 4, 0)).unwrap();
    let snap = coord.snapshot(open.sid).unwrap();
    // flip the recorded hash: restore must refuse, and must not leak a
    // half-open session
    let bad = SessionSnapshot {
        state_hash: snap.state_hash ^ 1,
        ..snap.clone()
    };
    assert!(coord.restore(&bad).unwrap_err().contains("hash mismatch"));
    // corrupt bitmap length
    let bad = SessionSnapshot {
        bits: vec![0u8; 1],
        ..snap.clone()
    };
    assert!(coord.restore(&bad).unwrap_err().contains("bitmap"));
    let sessions_open = coord.metrics().snapshot().sessions_open;
    assert_eq!(sessions_open, 1, "failed restores must not leak sessions");
    coord.join_jobs();
}

#[test]
fn snapshot_token_round_trips() {
    let coord = Coordinator::new(2);
    for engine in ["squeeze:4", "squeeze-bits:4:3"] {
        let open = coord.open(job(0, engine, 4, 0)).unwrap();
        coord.step(open.sid, 2).unwrap();
        let snap = coord.snapshot(open.sid).unwrap();
        let token = snap.to_token();
        assert!(
            !token.contains(char::is_whitespace),
            "token must be one protocol word: {token}"
        );
        assert_eq!(SessionSnapshot::parse(&token).unwrap(), snap);
    }
    assert!(SessionSnapshot::parse("garbage").is_err());
    assert!(SessionSnapshot::parse("SQZSNAP2;job=r=4;steps=0;hash=zz;state=00").is_err());
    coord.join_jobs();
}

#[test]
fn inspect_probes_agree_with_engine_state() {
    use squeeze::fractal::{catalog, Coord};
    use squeeze::maps::{lambda, MapCtx};
    let coord = Coordinator::new(2);
    let open = coord.open(job(0, "squeeze:4", 4, 0)).unwrap();
    let cells = open.cells;
    // the expanded embedding of compact cell 0, via λ — the At probe
    // must resolve it back through ν to the same cell
    let ctx = MapCtx::new(&catalog::sierpinski_triangle(), 4);
    let e0 = lambda(&ctx, Coord::new(0, 0));
    let info = coord
        .inspect(
            open.sid,
            &[Probe::Region(0, cells), Probe::Cell(0), Probe::At(e0.x, e0.y)],
        )
        .unwrap();
    match info.probes[0] {
        ProbeResult::Region { live, .. } => assert_eq!(live, info.population),
        ref other => panic!("unexpected probe result {other:?}"),
    }
    match (info.probes[1], info.probes[2]) {
        (ProbeResult::Cell { alive, .. }, ProbeResult::At { state, .. }) => {
            assert_eq!(state, Some(alive));
        }
        other => panic!("unexpected probe results {other:?}"),
    }
    // out-of-range probes are errors, not panics
    assert!(coord.inspect(open.sid, &[Probe::Cell(cells)]).is_err());
    assert!(coord.inspect(open.sid, &[Probe::Region(5, 4)]).is_err());
    coord.join_jobs();
}

#[test]
fn interleaved_sessions_match_serial_v1_line_protocol() {
    // N interleaved sessions (mixed byte/packed, single/sharded) stepped
    // concurrently through the typed API must hash identically to the
    // same jobs run serially, one at a time, through the v1 protocol.
    let (r, total_steps) = (5, 6);
    // serial reference through the v1 line protocol
    let script: String = LAYOUTS
        .iter()
        .map(|e| format!("engine={e} r={r} steps={total_steps} workers=1 seed=9 density=0.4\n"))
        .collect::<String>()
        + "quit\n";
    let mut out = Vec::new();
    serve(script.as_bytes(), &mut out).unwrap();
    let out = String::from_utf8(out).unwrap();
    assert!(!out.contains("ERR"), "{out}");
    let want: Vec<&str> = out
        .lines()
        .filter(|l| !l.starts_with('#') && l.split('\t').count() > 3)
        .map(|l| l.split('\t').last().unwrap())
        .collect();
    assert_eq!(want.len(), LAYOUTS.len(), "{out}");
    // all reference hashes agree with each other (same logical automaton)
    assert!(want.windows(2).all(|w| w[0] == w[1]), "{want:?}");

    // typed API: open all four, then interleave their steps from
    // concurrent threads (2 sessions per thread, alternating)
    let coord = Arc::new(Coordinator::new(4));
    let sids: Vec<u64> = LAYOUTS
        .iter()
        .map(|e| coord.open(job(0, e, r, 0)).unwrap().sid)
        .collect();
    assert_eq!(coord.metrics().snapshot().sessions_open, 4);
    std::thread::scope(|scope| {
        for pair in sids.chunks(2) {
            let coord = Arc::clone(&coord);
            scope.spawn(move || {
                for _ in 0..total_steps {
                    for &sid in pair {
                        coord.step(sid, 1).unwrap();
                    }
                }
            });
        }
    });
    for (i, &sid) in sids.iter().enumerate() {
        let info = coord.close(sid).unwrap();
        assert_eq!(info.steps_done, total_steps as u64);
        let hash = format!("{:#018x}", info.state_hash);
        assert_eq!(
            hash, want[i],
            "{}: interleaved session diverged from serial v1 run",
            LAYOUTS[i]
        );
    }
    assert_eq!(coord.metrics().snapshot().sessions_open, 0);
    coord.join_jobs();
}

#[test]
fn sessions_reuse_the_shared_map_cache() {
    let coord = Coordinator::new(2);
    let a = coord.open(job(0, "squeeze:4", 5, 0)).unwrap();
    let b = coord.open(job(0, "squeeze:4", 5, 0)).unwrap();
    let stats = coord.map_cache().stats();
    assert_eq!(stats.misses, 1, "{stats:?}");
    assert!(stats.hits >= 1, "{stats:?}");
    // and a job of the same shape hits too
    coord.submit(job(0, "squeeze:4", 5, 2)).wait().unwrap();
    assert_eq!(coord.map_cache().stats().misses, 1);
    coord.close(a.sid).unwrap();
    coord.close(b.sid).unwrap();
    coord.join_jobs();
}

#[test]
fn session_errors_are_messages_not_panics() {
    let coord = Coordinator::new(2);
    assert!(coord.step(7, 1).is_err());
    assert!(coord.close(7).is_err());
    assert!(coord.snapshot(7).is_err());
    assert!(coord
        .open(job(0, "squeeze:3", 5, 0))
        .unwrap_err()
        .contains("rho=3"));
    // engines without an import path reject restore cleanly: lambda has
    // load_state, so corrupt *spec* fractals fail at open instead
    let mut spec = job(0, "squeeze:4", 4, 0);
    spec.fractal = "not-a-fractal".into();
    assert!(coord.open(spec).unwrap_err().contains("unknown fractal"));
    assert_eq!(coord.metrics().snapshot().sessions_open, 0);
    coord.join_jobs();
}

#[test]
fn bb_and_lambda_sessions_snapshot_too() {
    // the canonical bitmap is engine-layout independent: expanded-space
    // engines snapshot/restore the same way
    let coord = Coordinator::new(2);
    for engine in ["bb", "lambda", "squeeze"] {
        let want = coord
            .submit(job(0, engine, 4, 4))
            .wait()
            .unwrap()
            .state_hash;
        let open = coord.open(job(0, engine, 4, 0)).unwrap();
        coord.step(open.sid, 2).unwrap();
        let snap = coord.snapshot(open.sid).unwrap();
        let restored = coord.restore(&snap).unwrap();
        let done = coord.step(restored.sid, 2).unwrap();
        assert_eq!(done.state_hash, want, "{engine}");
        coord.close(open.sid).unwrap();
        coord.close(restored.sid).unwrap();
    }
    coord.join_jobs();
}

#[test]
fn engine_kind_is_preserved_through_the_snapshot_spec() {
    // regression guard for the JobSpec::to_line round-trip inside the
    // token: a sharded packed engine must come back sharded and packed
    let coord = Coordinator::new(2);
    let open = coord.open(job(0, "squeeze-bits:4:3", 5, 0)).unwrap();
    let token = coord.snapshot(open.sid).unwrap().to_token();
    let parsed = SessionSnapshot::parse(&token).unwrap();
    assert_eq!(
        parsed.spec.engine,
        EngineKind::PackedShardedSqueeze { rho: 4, shards: 3 }
    );
    coord.join_jobs();
}
