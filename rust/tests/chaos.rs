//! Chaos integration: deterministic fault injection end to end. The
//! headline is the chaos differential — under a seeded fault schedule
//! (disk-write errors, fsync delays, dropped worker batches, one
//! injected engine panic), every surviving session's canonical hash
//! equals its fault-free twin's: faults cost retries, revives and
//! partial batches, never simulation results. The rest exercises the
//! self-healing machinery one piece at a time: quarantine fencing and
//! `revive`, the checkpoint circuit breaker, per-request deadlines,
//! the stall watchdog, and the everything-off baseline.

use std::path::{Path, PathBuf};
use std::time::Duration;

use squeeze::coordinator::{Coordinator, CoordinatorConfig, JobSpec};
use squeeze::net::{arm_faults, run_worker, ClusterListener};

/// Same layout corners as the durability suite: byte/packed ×
/// single/sharded.
const LAYOUTS: [&str; 4] = [
    "engine=squeeze:4 r=5 workers=1 seed=9 density=0.4",
    "engine=squeeze-bits:4 r=5 workers=1 seed=9 density=0.4",
    "engine=sharded-squeeze:4:3 r=5 workers=1 seed=9 density=0.4",
    "engine=squeeze-bits:4:3 r=5 workers=1 seed=9 density=0.4",
];

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("squeeze-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A durable coordinator with a fault plan armed and a fast breaker
/// probe (so a tripped breaker never wedges a retry loop for long).
fn chaos_config(dir: &Path, faults: &str, seed: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        budget: 2,
        data_dir: Some(dir.to_path_buf()),
        faults: Some(faults.to_string()),
        fault_seed: seed,
        breaker_probe_ms: 50,
        ..Default::default()
    }
}

/// The uninterrupted, fault-free twin's canonical hash for `line`.
fn twin_hash(line: &str, steps: u32) -> u64 {
    let twin = Coordinator::new(2);
    let info = twin.open(JobSpec::parse_line(0, line).unwrap()).unwrap();
    twin.step(info.sid, steps).unwrap();
    twin.close(info.sid).unwrap().state_hash
}

/// Arm durability the way a robust client would: retry the initial
/// checkpoint through injected write errors (waiting out a tripped
/// breaker's probe window between attempts).
fn persist_robustly(coord: &Coordinator, sid: u64) {
    for _ in 0..40 {
        if coord.persist(sid, Some(1), None).is_ok() {
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    panic!("session {sid}: initial checkpoint never landed");
}

/// Drive `sid` to `target` lifetime steps through whatever the fault
/// plan throws: re-issue after partial batches, `revive` after a
/// quarantine. Bounded so a wedged coordinator fails the test instead
/// of hanging it.
fn step_to(coord: &Coordinator, sid: u64, target: u64) {
    for _ in 0..400 {
        let info = coord.inspect(sid, &[]).unwrap();
        if info.steps_done >= target {
            return;
        }
        let want = (target - info.steps_done) as u32;
        match coord.step(sid, want) {
            Ok(_) => {}
            Err(e) if e.contains("quarantined") => {
                coord
                    .revive(sid)
                    .unwrap_or_else(|r| panic!("step: {e}\nrevive: {r}"));
            }
            // partial progress was kept — re-inspect and go again
            Err(_) => {}
        }
    }
    panic!("session {sid} never reached {target} steps");
}

#[test]
fn chaos_differential_matches_fault_free_twin_across_seeds_and_layouts() {
    // the panic rule leads so its one-shot trigger cannot be shadowed
    // by a probabilistic rule firing on the same check — every run is
    // guaranteed one quarantine + revive cycle
    const PLAN: &str = "worker:panic@step=6;store.write:err@0.3;\
                        store.fsync:delay=1ms@0.1;worker:err@0.2";
    for seed in [1u64, 2, 3] {
        for (i, line) in LAYOUTS.iter().enumerate() {
            let want = twin_hash(line, 8);
            let dir = tmpdir(&format!("diff-{seed}-{i}"));
            let coord = Coordinator::with_config(chaos_config(&dir, PLAN, seed));
            let sid = coord.open(JobSpec::parse_line(0, line).unwrap()).unwrap().sid;
            persist_robustly(&coord, sid);
            step_to(&coord, sid, 8);
            let closed = coord.close(sid).unwrap();
            assert_eq!(closed.steps_done, 8, "seed {seed} layout {line}");
            assert_eq!(
                closed.state_hash, want,
                "seed {seed} layout {line}: surviving hash diverged from twin"
            );
            // the schedule really fired: the one-shot panic quarantined
            // the session once and revive brought it back
            assert!(coord.fault_plan().unwrap().injected() > 0);
            let snap = coord.metrics().snapshot();
            assert!(snap.revives >= 1, "seed {seed} layout {line}: {snap:?}");
            assert_eq!(snap.quarantined, 0, "seed {seed} layout {line}: {snap:?}");
            drop(coord);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn quarantine_fences_step_until_revive_rebuilds_from_checkpoint() {
    let line = LAYOUTS[0];
    let dir = tmpdir("quarantine");
    let coord = Coordinator::with_config(chaos_config(&dir, "worker:panic@step=3", 7));
    let sid = coord.open(JobSpec::parse_line(0, line).unwrap()).unwrap().sid;
    coord.persist(sid, Some(1), None).unwrap();

    // the third per-step fault check panics mid-sweep: the session is
    // fenced, not torn, not closed
    let err = coord.step(sid, 5).unwrap_err();
    assert!(err.contains("quarantined"), "{err}");
    assert!(err.contains("revive"), "{err}");

    // fenced: step and relayout answer the structured error, inspect
    // still works for debugging
    let again = coord.step(sid, 1).unwrap_err();
    assert!(again.contains("quarantined"), "{again}");
    let relayout = coord.relayout(sid, "squeeze-bits:4").unwrap_err();
    assert!(relayout.contains("quarantined"), "{relayout}");
    assert!(coord.inspect(sid, &[]).is_ok());
    assert_eq!(coord.metrics().snapshot().quarantined, 1);

    // revive rebuilds from the last checkpoint (step 0 here) and lifts
    // the fence; the finished run still matches the fault-free twin
    let info = coord.revive(sid).unwrap();
    assert_eq!(info.steps_done, 0);
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.quarantined, 0, "{snap:?}");
    assert_eq!(snap.revives, 1, "{snap:?}");
    assert!(coord.revive(sid).unwrap_err().contains("not quarantined"));
    coord.step(sid, 6).unwrap();
    assert_eq!(coord.close(sid).unwrap().state_hash, twin_hash(line, 6));
    drop(coord);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_breaker_trips_after_repeated_failures_and_probes_half_open() {
    let dir = tmpdir("breaker");
    // every store write fails, deterministically; cadence 0/0 so only
    // explicit persist calls touch the store
    let coord = Coordinator::with_config(chaos_config(&dir, "store.write:err@n=1", 0));
    let sid = coord.open(JobSpec::parse_line(0, LAYOUTS[0]).unwrap()).unwrap().sid;

    // three straight failures (each with its own bounded retry) trip
    // the breaker
    for _ in 0..3 {
        let err = coord.persist(sid, Some(0), Some(0)).unwrap_err();
        assert!(err.contains("injected"), "{err}");
    }
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.breaker_trips, 1, "{snap:?}");
    assert_eq!(snap.breaker_open, 1, "{snap:?}");
    assert!(snap.store_retries >= 2, "{snap:?}");

    // open: the store is not even touched
    let open = coord.persist(sid, Some(0), Some(0)).unwrap_err();
    assert!(open.contains("circuit breaker open"), "{open}");
    // stepping is unaffected by a cooling-down checkpoint path
    assert_eq!(coord.step(sid, 2).unwrap().steps_done, 2);

    // after the probe window one half-open attempt is admitted; it
    // still fails, so the breaker re-trips and closes the gate again
    std::thread::sleep(Duration::from_millis(70));
    let probed = coord.persist(sid, Some(0), Some(0)).unwrap_err();
    assert!(probed.contains("injected"), "{probed}");
    let reopen = coord.persist(sid, Some(0), Some(0)).unwrap_err();
    assert!(reopen.contains("circuit breaker open"), "{reopen}");
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.breaker_trips, 2, "{snap:?}");
    assert_eq!(snap.breaker_open, 1, "{snap:?}");

    // closing the session retires its open breaker from the gauge
    coord.close(sid).unwrap();
    assert_eq!(coord.metrics().snapshot().breaker_open, 0);
    drop(coord);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn deadline_keeps_partial_progress_and_the_run_still_matches_the_twin() {
    let line = LAYOUTS[0];
    // every step pays a 10ms injected delay against a 35ms budget: a
    // 10-step request must come back partial
    let coord = Coordinator::with_config(CoordinatorConfig {
        budget: 2,
        faults: Some("worker:delay=10ms@n=1".to_string()),
        fault_seed: 0,
        deadline_ms: 35,
        ..Default::default()
    });
    let sid = coord.open(JobSpec::parse_line(0, line).unwrap()).unwrap().sid;
    let err = coord.step(sid, 10).unwrap_err();
    assert!(err.contains("deadline exceeded"), "{err}");
    assert!(err.contains("progress kept"), "{err}");
    let done = coord.inspect(sid, &[]).unwrap().steps_done;
    assert!(done > 0 && done < 10, "stepped {done}");
    assert!(coord.metrics().snapshot().deadline_exceeded >= 1);

    // a client that re-issues gets there, and lands on the twin hash —
    // deadlines shed load, they do not corrupt state
    step_to(&coord, sid, 10);
    assert_eq!(coord.close(sid).unwrap().state_hash, twin_hash(line, 10));
}

#[test]
fn watchdog_cancels_a_stalled_job_with_a_structured_reason() {
    // the fifth worker fault check stalls 400ms against a 60ms
    // no-progress threshold
    let coord = Coordinator::with_config(CoordinatorConfig {
        budget: 2,
        faults: Some("worker:stall=400ms@step=5".to_string()),
        fault_seed: 0,
        watchdog_ms: 60,
        ..Default::default()
    });
    let spec = JobSpec::parse_line(
        0,
        "engine=squeeze:4 r=5 workers=1 seed=9 density=0.4 steps=50000",
    )
    .unwrap();
    let handle = coord.submit(spec);
    let err = handle.wait().unwrap_err();
    assert!(err.contains("watchdog"), "{err}");
    assert!(err.contains("no progress"), "{err}");
    assert_eq!(coord.metrics().snapshot().watchdog_cancels, 1);
}

#[test]
fn net_faults_quarantine_a_cluster_session_and_revive_rebuilds_it() {
    let single = "engine=sharded-squeeze:4:4 r=5 workers=1 seed=9 density=0.4";
    let want = twin_hash(single, 6);
    let line = "engine=sharded-squeeze:4:4@hosts=2 r=5 workers=1 seed=9 density=0.4";
    let dir = tmpdir("net");
    let listener = ClusterListener::start("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().to_string();
    let spawn_worker = |addr: &str| {
        let addr = addr.to_string();
        std::thread::spawn(move || run_worker(&addr, Some(1)))
    };
    let w1 = spawn_worker(&addr);
    // the very first transport send errors; workers=1 keeps the
    // exchange serial so the full reason reaches the quarantine record
    let coord = Coordinator::with_config(chaos_config(&dir, "net.send:err@step=1", 5));
    let sid = coord.open(JobSpec::parse_line(0, line).unwrap()).unwrap().sid;
    coord.persist(sid, Some(1), None).unwrap();
    // mirror the CLI's serve wiring: the coordinator's one plan also
    // covers the transport seams
    arm_faults(coord.fault_plan());
    let err = coord.step(sid, 6).unwrap_err();
    arm_faults(None);
    assert!(err.contains("quarantined"), "{err}");
    assert_eq!(coord.metrics().snapshot().quarantined, 1);
    assert!(coord.fault_plan().unwrap().injected() >= 1);
    // revive rebuilds the placement from the checkpoint — the rebuild
    // claims a freshly joined worker and its engine swap releases the
    // fenced one
    let w2 = spawn_worker(&addr);
    coord.revive(sid).unwrap();
    let _ = w1.join().unwrap();
    coord.step(sid, 6).unwrap();
    let closed = coord.close(sid).unwrap();
    assert_eq!(closed.steps_done, 6);
    assert_eq!(closed.state_hash, want, "revived cluster diverged from twin");
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.quarantined, 0, "{snap:?}");
    assert_eq!(snap.revives, 1, "{snap:?}");
    w2.join().unwrap().unwrap();
    drop(coord);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn without_a_fault_plan_nothing_changes_and_every_gauge_stays_zero() {
    let line = LAYOUTS[1];
    let coord = Coordinator::with_config(CoordinatorConfig {
        budget: 2,
        ..Default::default()
    });
    assert!(coord.fault_plan().is_none());
    let sid = coord.open(JobSpec::parse_line(0, line).unwrap()).unwrap().sid;
    coord.step(sid, 6).unwrap();
    assert_eq!(coord.close(sid).unwrap().state_hash, twin_hash(line, 6));
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.store_retries, 0, "{snap:?}");
    assert_eq!(snap.deadline_exceeded, 0, "{snap:?}");
    assert_eq!(snap.watchdog_cancels, 0, "{snap:?}");
    assert_eq!(snap.idle_reaped, 0, "{snap:?}");
    assert_eq!(snap.quarantined, 0, "{snap:?}");
    assert_eq!(snap.revives, 0, "{snap:?}");
    assert_eq!(snap.breaker_trips, 0, "{snap:?}");
    assert_eq!(snap.breaker_open, 0, "{snap:?}");
}
