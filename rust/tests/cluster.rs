//! Multi-process differential: the cluster transport row of the
//! correctness matrix. An `@hosts=N` placement runs the same halo
//! exchange as the single-process sharded engine with a socket where
//! the staging `Vec` sits, so for every rule in the matrix a 2-process
//! cluster (byte and packed backends) must produce the same
//! `state_hash()` as its single-process twin and the expanded BB
//! reference after *every* step — plus a 3-process spot check (the
//! relay path through the hub), the query/load fan-out, and the
//! fail-closed seam: an injected `net.send`/`net.recv` fault must
//! panic the step (→ quarantine upstream), never wedge or corrupt it.
//!
//! Workers run as in-process threads driving the real `run_worker`
//! serve loop over real TCP sockets; the joined-worker pool is
//! process-global, so every test serializes on one lock and drains
//! what it spawns.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;

use squeeze::ca::{build, Engine, EngineConfig, EngineKind, Rule};
use squeeze::coordinator::FaultPlan;
use squeeze::fractal::{catalog, FractalSpec};
use squeeze::net::{self, ClusterListener};

/// Same rule matrix as the differential suite: Conway, HighLife, Seeds,
/// the still-life boundary rule, and an asymmetric birth-heavy rule.
const RULES: &[&str] = &["B3/S23", "B36/S23", "B2/S", "B/S012345678", "B13/S0123"];

/// The joined-worker pool and the transport fault cell are
/// process-global; cluster tests take this lock so one test's workers
/// are never claimed by another's build.
fn lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn config(kind: EngineKind, hosts: u32, rule: Rule) -> EngineConfig {
    EngineConfig {
        kind,
        r: 5,
        rule,
        density: 0.45,
        seed: 0xD1FF,
        workers: 2,
        hosts,
        ..Default::default()
    }
}

/// A live cluster: the coordinator-side engine plus one serve-loop
/// thread per worker process stand-in.
struct Cluster {
    engine: Box<dyn Engine>,
    workers: Vec<JoinHandle<Result<(), String>>>,
}

impl Cluster {
    /// Start a listener on an ephemeral port, spawn `hosts - 1`
    /// workers, and build the coordinator engine (which claims them).
    fn start(spec: &FractalSpec, cfg: &EngineConfig) -> Cluster {
        let listener = ClusterListener::start("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().to_string();
        let workers: Vec<_> = (1..cfg.hosts)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || net::run_worker(&addr, Some(1)))
            })
            .collect();
        let engine = build(spec, cfg).unwrap();
        Cluster { engine, workers }
    }

    /// Drop the engine (its `Bye` releases the serve loops) and verify
    /// every worker exited cleanly.
    fn shutdown(self) {
        drop(self.engine);
        for worker in self.workers {
            worker.join().unwrap().unwrap();
        }
    }

    /// Tear down after an induced failure: workers may exit either way
    /// (clean `Bye` or a failed serve loop), but they must exit.
    fn shutdown_after_failure(self) {
        drop(self.engine);
        for worker in self.workers {
            let _ = worker.join().unwrap();
        }
    }
}

#[test]
fn two_process_byte_and_packed_match_single_process_and_bb_for_every_rule() {
    let _guard = lock();
    let spec = catalog::sierpinski_triangle();
    for rule_text in RULES {
        let rule = Rule::parse(rule_text).unwrap();
        for kind in [
            EngineKind::ShardedSqueeze { rho: 2, shards: 4 },
            EngineKind::PackedShardedSqueeze { rho: 2, shards: 4 },
        ] {
            let mut bb = build(&spec, &config(EngineKind::Bb, 1, rule)).unwrap();
            let mut single = build(&spec, &config(kind, 1, rule)).unwrap();
            let cfg = config(kind, 2, rule);
            let mut cluster = Cluster::start(&spec, &cfg);
            assert!(
                cluster.engine.name().ends_with("@hosts=2"),
                "{}",
                cluster.engine.name()
            );
            for step in 0..6 {
                bb.step();
                single.step();
                cluster.engine.step();
                let want = bb.state_hash();
                assert_eq!(
                    single.state_hash(),
                    want,
                    "single {kind:?} rule {rule_text} step {step}"
                );
                assert_eq!(
                    cluster.engine.state_hash(),
                    want,
                    "cluster {kind:?} rule {rule_text} step {step}"
                );
            }
            assert_eq!(cluster.engine.population(), single.population(), "{rule_text}");
            cluster.shutdown();
        }
    }
    assert_eq!(net::pending_workers(), 0);
}

#[test]
fn three_process_relay_queries_and_load_agree_with_the_single_twin() {
    let _guard = lock();
    let spec = catalog::sierpinski_triangle();
    let rule = Rule::parse("B3/S23").unwrap();
    let kind = EngineKind::ShardedSqueeze { rho: 2, shards: 4 };
    let mut single = build(&spec, &config(kind, 1, rule)).unwrap();
    let mut cluster = Cluster::start(&spec, &config(kind, 3, rule));
    assert!(cluster.engine.name().ends_with("@hosts=3"));
    for _ in 0..4 {
        single.step();
        cluster.engine.step();
    }
    assert_eq!(cluster.engine.state_hash(), single.state_hash());
    assert_eq!(cluster.engine.population(), single.population());
    // per-cell queries fan out to whichever process owns the cell
    let cells = single.cells();
    for idx in (0..cells).step_by((cells / 16).max(1) as usize) {
        assert_eq!(cluster.engine.cell(idx), single.cell(idx), "cell {idx}");
    }
    // the load fan-out rebuilds every process's owned state: rewind the
    // cluster to the twin's exported bitmap and both keep agreeing
    let bits = single.export_state();
    cluster.engine.load_state(&bits).unwrap();
    assert_eq!(cluster.engine.state_hash(), single.state_hash());
    for _ in 0..2 {
        single.step();
        cluster.engine.step();
    }
    assert_eq!(cluster.engine.state_hash(), single.state_hash());
    cluster.shutdown();
    assert_eq!(net::pending_workers(), 0);
}

#[test]
fn injected_send_fault_panics_the_step_and_delay_faults_cost_only_latency() {
    let _guard = lock();
    let spec = catalog::sierpinski_triangle();
    let rule = Rule::parse("B3/S23").unwrap();
    let kind = EngineKind::ShardedSqueeze { rho: 2, shards: 4 };

    // a delayed frame is pure latency: the step completes and the hash
    // still matches the twin
    let mut single = build(&spec, &config(kind, 1, rule)).unwrap();
    let mut cluster = Cluster::start(&spec, &config(kind, 2, rule));
    let delay = FaultPlan::parse("net.recv:delay=1ms@step=1", 3).unwrap();
    net::arm_faults(Some(Arc::new(delay)));
    cluster.engine.step();
    net::arm_faults(None);
    single.step();
    assert_eq!(cluster.engine.state_hash(), single.state_hash());
    cluster.shutdown();

    // a failed send errors the exchange, which must panic the step —
    // upstream, the coordinator's catch-unwind turns exactly this panic
    // into a quarantined session (chaos suite), never a silent skip.
    // workers=1 keeps the exchange on the calling thread so the panic
    // payload (not the scope's replacement) reaches the catch.
    let serial = EngineConfig { workers: 1, ..config(kind, 2, rule) };
    let mut cluster = Cluster::start(&spec, &serial);
    cluster.engine.step();
    let err = FaultPlan::parse("net.send:err@step=1", 3).unwrap();
    let plan = Arc::new(err);
    net::arm_faults(Some(Arc::clone(&plan)));
    let payload = catch_unwind(AssertUnwindSafe(|| cluster.engine.step())).unwrap_err();
    net::arm_faults(None);
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| "opaque panic".to_string());
    assert!(msg.contains("cluster halo exchange failed"), "{msg}");
    assert!(msg.contains("injected fault at net.send"), "{msg}");
    assert_eq!(plan.injected(), 1);
    // the failed step is fenced, not wedged: teardown still completes
    cluster.shutdown_after_failure();
    assert_eq!(net::pending_workers(), 0);
}

#[test]
fn cluster_builds_fail_closed_without_enough_workers() {
    let _guard = lock();
    let spec = catalog::sierpinski_triangle();
    let rule = Rule::parse("B3/S23").unwrap();
    // no listener, no workers: the claim times out with the hint
    let cfg = config(EngineKind::ShardedSqueeze { rho: 2, shards: 4 }, 2, rule);
    let before = std::time::Instant::now();
    let err = build(&spec, &cfg).map(|_| ()).unwrap_err().to_string();
    assert!(err.contains("squeeze worker --join"), "{err}");
    // the join timeout bounds the wait (10s) — it must actually wait,
    // not fail instantly on an empty pool race
    assert!(before.elapsed().as_secs() < 60);
}
