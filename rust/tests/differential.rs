//! Differential test suite — the correctness oracle for the map-cache +
//! parallel tiled stepping subsystem.
//!
//! All engines simulate the *same logical automaton* (see
//! `ca::engine`), so for every catalog fractal and every rule in the
//! matrix below, the expanded BB reference, the thread-level Squeeze
//! engine, the block-level Squeeze engine (serial and parallel, cached
//! and uncached, scalar and tensor-path), the halo-exchanged sharded
//! decomposition (1, 2, and 4 shards — with every `overlap on/off ×
//! compaction on/off` combination of the unified exchange), and the
//! bit-planar `squeeze-bits` backends (serial/parallel ×
//! cached/uncached, plus sharded-packed at 1/2/4 shards and the same
//! overlap/compaction matrix), the flat bit-planar `bb-bits` twin, and
//! the MMA rule lift (`squeeze-bits:<ρ>:mma`, single and sharded) must
//! produce identical `state_hash()`
//! after *every* step — not just at the end. A divergence at step `t`
//! localizes a bug to one transition, which is what makes this suite
//! the oracle the cache/parallelism/sharding/bit-packing/backend-trait
//! refactors are tested against.

use squeeze::ca::{build_with_cache, Engine, EngineConfig, EngineKind, Rule};
use squeeze::fractal::catalog;
use squeeze::maps::MapCache;

/// Rule matrix: Conway, HighLife, Seeds (no survival), the still-life
/// boundary rule (no birth, total survival), and an asymmetric
/// birth-heavy rule — together they exercise every branch of
/// `Rule::next_u8` (birth-only, survive-only, mixed masks).
const RULES: &[&str] = &["B3/S23", "B36/S23", "B2/S", "B/S012345678", "B13/S0123"];

/// Level per fractal, sized so the expanded BB reference stays cheap
/// while every engine still crosses block boundaries: s=2 fractals get
/// r=5 (n=32), s=3 fractals r=3 (n=27).
fn level_for(s: u32) -> u32 {
    if s == 2 {
        5
    } else {
        3
    }
}

#[test]
fn every_engine_agrees_with_bb_for_every_fractal_and_rule() {
    let cache = MapCache::new();
    let steps = 8;
    for spec in catalog::all() {
        let r = level_for(spec.s);
        let rho = spec.s; // one intra level
        let rho2 = spec.s * spec.s; // two intra levels (fits: r >= 2·1)
        for rule_text in RULES {
            let rule = Rule::parse(rule_text).expect("rule matrix entry parses");
            let cfg = |kind: EngineKind, workers: usize| EngineConfig {
                kind,
                r,
                rule,
                density: 0.45,
                seed: 0xD1FF,
                workers,
                ..Default::default()
            };
            // the default sharded rows run the overlap+compaction path;
            // this builds the other three exchange-mode combinations
            let cfg_mode = |kind: EngineKind, overlap: bool, compact: bool| EngineConfig {
                overlap,
                compact,
                ..cfg(kind, 4)
            };
            let mut engines = vec![
                (
                    "bb",
                    build_with_cache(&spec, &cfg(EngineKind::Bb, 2), None).unwrap(),
                ),
                (
                    "bb-bits",
                    build_with_cache(&spec, &cfg(EngineKind::PackedBb, 2), None).unwrap(),
                ),
                (
                    "lambda",
                    build_with_cache(&spec, &cfg(EngineKind::Lambda, 2), Some(&cache)).unwrap(),
                ),
                (
                    "squeeze-thread",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::Squeeze { rho: 1, tensor: false }, 2),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
                (
                    "squeeze-block-serial",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::Squeeze { rho, tensor: false }, 1),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
                (
                    "squeeze-block-parallel",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::Squeeze { rho, tensor: false }, 4),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
                (
                    "squeeze-block-parallel-uncached",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::Squeeze { rho, tensor: false }, 4),
                        None,
                    )
                    .unwrap(),
                ),
                (
                    "squeeze-block-rho2-parallel",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::Squeeze { rho: rho2, tensor: false }, 4),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
                (
                    "sharded-squeeze-1",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::ShardedSqueeze { rho, shards: 1 }, 2),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
                (
                    "sharded-squeeze-2",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::ShardedSqueeze { rho, shards: 2 }, 4),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
                (
                    "sharded-squeeze-4",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::ShardedSqueeze { rho, shards: 4 }, 4),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
                (
                    "squeeze-bits-serial",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::PackedSqueeze { rho }, 1),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
                (
                    "squeeze-bits-parallel",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::PackedSqueeze { rho }, 4),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
                (
                    "squeeze-bits-parallel-uncached",
                    build_with_cache(&spec, &cfg(EngineKind::PackedSqueeze { rho }, 4), None)
                        .unwrap(),
                ),
                (
                    "squeeze-bits-rho2-parallel",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::PackedSqueeze { rho: rho2 }, 4),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
                (
                    "squeeze-bits-mma",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::PackedMmaSqueeze { rho }, 2),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
                (
                    "sharded-squeeze-bits-mma-2",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::PackedMmaShardedSqueeze { rho, shards: 2 }, 4),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
                (
                    "sharded-squeeze-bits-1",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::PackedShardedSqueeze { rho, shards: 1 }, 2),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
                (
                    "sharded-squeeze-bits-2",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::PackedShardedSqueeze { rho, shards: 2 }, 4),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
                (
                    "sharded-squeeze-bits-4",
                    build_with_cache(
                        &spec,
                        &cfg(EngineKind::PackedShardedSqueeze { rho, shards: 4 }, 4),
                        Some(&cache),
                    )
                    .unwrap(),
                ),
            ];
            // overlap on/off × compaction on/off, byte and packed (the
            // on/on cell is the default path the rows above already run)
            for (overlap, compact, tag) in [
                (false, true, "serial-compact"),
                (true, false, "overlap-full"),
                (false, false, "serial-full"),
            ] {
                engines.push((
                    match tag {
                        "serial-compact" => "sharded-squeeze-2-serial-compact",
                        "overlap-full" => "sharded-squeeze-2-overlap-full",
                        _ => "sharded-squeeze-2-serial-full",
                    },
                    build_with_cache(
                        &spec,
                        &cfg_mode(EngineKind::ShardedSqueeze { rho, shards: 2 }, overlap, compact),
                        Some(&cache),
                    )
                    .unwrap(),
                ));
                engines.push((
                    match tag {
                        "serial-compact" => "sharded-squeeze-bits-2-serial-compact",
                        "overlap-full" => "sharded-squeeze-bits-2-overlap-full",
                        _ => "sharded-squeeze-bits-2-serial-full",
                    },
                    build_with_cache(
                        &spec,
                        &cfg_mode(
                            EngineKind::PackedShardedSqueeze { rho, shards: 2 },
                            overlap,
                            compact,
                        ),
                        Some(&cache),
                    )
                    .unwrap(),
                ));
            }
            let seed_hash = engines[0].1.state_hash();
            for (name, e) in &engines {
                assert_eq!(
                    e.state_hash(),
                    seed_hash,
                    "{} rule={rule_text} engine={name}: seed state diverged",
                    spec.name
                );
            }
            for step in 1..=steps {
                let mut reference = 0u64;
                for (i, (name, e)) in engines.iter_mut().enumerate() {
                    e.step();
                    let h = e.state_hash();
                    if i == 0 {
                        reference = h;
                    } else {
                        assert_eq!(
                            h, reference,
                            "{} rule={rule_text} engine={name} diverged from bb at step {step}",
                            spec.name
                        );
                    }
                }
            }
        }
    }
    // the differential matrix itself must have exercised cache sharing
    assert!(cache.stats().hits > 0, "{:?}", cache.stats());
}

#[test]
fn tensor_path_engines_agree_with_scalar_inside_fp16_envelope() {
    let cache = MapCache::new();
    for spec in catalog::all() {
        let r = level_for(spec.s);
        let rho = spec.s;
        let cfg = |tensor: bool| EngineConfig {
            kind: EngineKind::Squeeze { rho, tensor },
            r,
            rule: Rule::game_of_life(),
            density: 0.4,
            seed: 99,
            workers: 2,
            ..Default::default()
        };
        let mut scalar = build_with_cache(&spec, &cfg(false), Some(&cache)).unwrap();
        let mut tensor = build_with_cache(&spec, &cfg(true), Some(&cache)).unwrap();
        for step in 1..=8 {
            scalar.step();
            tensor.step();
            assert_eq!(
                scalar.state_hash(),
                tensor.state_hash(),
                "{} tensor path diverged at step {step}",
                spec.name
            );
        }
    }
}

#[test]
fn long_run_agreement_on_the_paper_headline_fractal() {
    // 30 steps on the Sierpinski triangle at r=6 across the full engine
    // set, through the factory exactly as the coordinator builds them.
    let cache = MapCache::new();
    let spec = catalog::sierpinski_triangle();
    let kinds = [
        EngineKind::Bb,
        EngineKind::Lambda,
        EngineKind::Squeeze { rho: 1, tensor: false },
        EngineKind::Squeeze { rho: 4, tensor: false },
        EngineKind::Squeeze { rho: 8, tensor: false },
        EngineKind::Squeeze { rho: 8, tensor: true },
        EngineKind::ShardedSqueeze { rho: 8, shards: 4 },
        EngineKind::PackedSqueeze { rho: 8 },
        EngineKind::PackedShardedSqueeze { rho: 8, shards: 4 },
        EngineKind::PackedBb,
        EngineKind::PackedMmaSqueeze { rho: 8 },
        EngineKind::PackedMmaShardedSqueeze { rho: 8, shards: 4 },
    ];
    let mut hashes = Vec::new();
    for kind in kinds {
        let mut e = build_with_cache(
            &spec,
            &EngineConfig {
                kind,
                r: 6,
                rule: Rule::game_of_life(),
                density: 0.4,
                seed: 42,
                workers: 3,
                ..Default::default()
            },
            Some(&cache),
        )
        .unwrap();
        for _ in 0..30 {
            e.step();
        }
        hashes.push((e.name(), e.state_hash(), e.population()));
    }
    let (first_hash, first_pop) = (hashes[0].1, hashes[0].2);
    for (name, h, p) in &hashes {
        assert_eq!(*h, first_hash, "{name} hash diverged: {hashes:?}");
        assert_eq!(*p, first_pop, "{name} population diverged");
    }
}
