//! Durability integration: the kill-crash differential (a session
//! checkpointed mid-run, crash-recovered from the store, and stepped to
//! completion must be hash-identical to an uninterrupted twin — for
//! byte and packed backends, single and sharded), corrupt-store
//! recovery, live relayout across the layout matrix, and the protocol
//! round-trip (`persist`/`recover` verbs through `serve_with`).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use squeeze::coordinator::{serve_with, Coordinator, CoordinatorConfig, JobSpec};

/// One open line per layout corner: byte/packed × single/sharded.
const LAYOUTS: [&str; 4] = [
    "engine=squeeze:4 r=5 workers=1 seed=9 density=0.4",
    "engine=squeeze-bits:4 r=5 workers=1 seed=9 density=0.4",
    "engine=sharded-squeeze:4:3 r=5 workers=1 seed=9 density=0.4",
    "engine=squeeze-bits:4:3 r=5 workers=1 seed=9 density=0.4",
];

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("squeeze-durable-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn durable_config(dir: &Path) -> CoordinatorConfig {
    CoordinatorConfig {
        budget: 2,
        data_dir: Some(dir.to_path_buf()),
        ..Default::default()
    }
}

/// The 6-step uninterrupted twin's canonical hash for `line`.
fn twin_hash(line: &str, steps: u32) -> u64 {
    let twin = Coordinator::new(2);
    let info = twin.open(JobSpec::parse_line(0, line).unwrap()).unwrap();
    twin.step(info.sid, steps).unwrap();
    twin.close(info.sid).unwrap().state_hash
}

#[test]
fn crash_recovery_matches_uninterrupted_twin_across_layouts() {
    for (i, line) in LAYOUTS.iter().enumerate() {
        let dir = tmpdir(&format!("diff{i}"));
        let want = twin_hash(line, 6);

        // durable run: checkpoint every step, then "crash" — drop the
        // coordinator mid-run with no close and no graceful shutdown
        let coord = Coordinator::with_config(durable_config(&dir));
        let spec = JobSpec::parse_line(0, line).unwrap();
        let sid = coord.open(spec.clone()).unwrap().sid;
        coord.persist(sid, Some(1), None).unwrap();
        coord.step(sid, 3).unwrap();
        drop(coord);

        // restart on the same data dir: recovered at step 3, then the
        // continued run lands on the uninterrupted hash
        let coord = Coordinator::with_config(durable_config(&dir));
        let report = coord.recovery().expect("recovery report");
        assert_eq!(report.recovered, vec![sid], "layout {line}: {report:?}");
        assert!(report.skipped.is_empty(), "layout {line}: {report:?}");
        let info = coord.step(sid, 3).unwrap();
        assert_eq!(info.steps_done, 6, "layout {line}");
        assert_eq!(coord.close(sid).unwrap().state_hash, want, "layout {line}");

        // fresh ids resume past the recovered high-water mark
        let fresh = coord.open(spec).unwrap();
        assert!(fresh.sid > sid, "sid {} not past recovered {sid}", fresh.sid);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn recovery_skips_corrupt_files_and_recovers_the_rest() {
    let dir = tmpdir("corrupt");
    let coord = Coordinator::with_config(durable_config(&dir));
    let a = coord.open(JobSpec::parse_line(0, LAYOUTS[0]).unwrap()).unwrap().sid;
    let b = coord.open(JobSpec::parse_line(0, LAYOUTS[1]).unwrap()).unwrap().sid;
    coord.persist(a, Some(1), None).unwrap();
    coord.persist(b, Some(1), None).unwrap();
    coord.step(a, 2).unwrap();
    coord.step(b, 2).unwrap();
    drop(coord);

    // session a's log becomes garbage end to end; a stray truncated
    // file rides along in the directory
    std::fs::write(dir.join(format!("sess-{a}.ckpt")), b"not a checkpoint at all").unwrap();
    std::fs::write(dir.join("sess-999.ckpt"), vec![0u8; 7]).unwrap();

    let coord = Coordinator::with_config(durable_config(&dir));
    let report = coord.recovery().expect("recovery report");
    assert_eq!(report.recovered, vec![b], "{report:?}");
    assert_eq!(report.skipped.len(), 2, "{report:?}");
    // the survivor still steps; the wreck is a clean error, not a panic
    // or a silently-loaded torn state
    assert_eq!(coord.step(b, 1).unwrap().steps_done, 3);
    assert!(coord.step(a, 1).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_checkpoint_recovers_at_its_last_intact_record() {
    let dir = tmpdir("stale");
    let coord = Coordinator::with_config(durable_config(&dir));
    let sid = coord.open(JobSpec::parse_line(0, LAYOUTS[0]).unwrap()).unwrap().sid;
    coord.persist(sid, Some(1), None).unwrap();
    coord.step(sid, 1).unwrap();
    coord.step(sid, 1).unwrap();
    drop(coord);

    // tear the tail: chop bytes off the end of the log, clipping the
    // newest record — recovery must fall back to the previous one
    let path = dir.join(format!("sess-{sid}.ckpt"));
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();

    let coord = Coordinator::with_config(durable_config(&dir));
    let report = coord.recovery().expect("recovery report");
    assert_eq!(report.recovered, vec![sid], "{report:?}");
    // the torn tail is reported, not fatal
    assert_eq!(report.skipped.len(), 1, "{report:?}");
    assert!(report.skipped[0].1.contains("torn tail"), "{report:?}");
    // recovered at step 1: finishing the run still matches the twin
    let info = coord.step(sid, 5).unwrap();
    assert_eq!(info.steps_done, 6);
    assert_eq!(coord.close(sid).unwrap().state_hash, twin_hash(LAYOUTS[0], 6));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn relayout_matrix_preserves_hash_and_fails_closed() {
    let want = twin_hash(LAYOUTS[0], 8);
    let coord = Coordinator::new(2);
    let sid = coord.open(JobSpec::parse_line(0, LAYOUTS[0]).unwrap()).unwrap().sid;
    // byte single → packed single → byte sharded → packed sharded →
    // back to byte single, stepping between relayouts
    let targets = ["squeeze-bits:4", "sharded-squeeze:4:3", "squeeze-bits:4:2", "squeeze:4"];
    for (k, target) in targets.iter().enumerate() {
        let before = coord.step(sid, 2).unwrap().state_hash;
        let info = coord.relayout(sid, target).unwrap();
        assert_eq!(info.state_hash, before, "relayout {target} changed state");
        assert_eq!(info.steps_done, 2 * (k as u64 + 1), "relayout {target}");
    }
    // a bogus target fails closed: error surfaced, session unharmed
    assert!(coord.relayout(sid, "warp-drive:3").is_err());
    assert!(coord.relayout(9999, "squeeze:4").is_err());
    let closed = coord.close(sid).unwrap();
    assert_eq!(closed.steps_done, 8);
    assert_eq!(closed.state_hash, want);
}

#[test]
fn checkpoint_all_racing_concurrent_steps_recovers_consistent_sessions() {
    // the drain-path race: `checkpoint_all` (the graceful-shutdown
    // sweep) runs while stepper threads are mid-flight on the same
    // sessions. Every sweep must see both sessions (a skipped or torn
    // one would drop out), and the records it writes must be
    // consistent snapshots a restart can serve from.
    let dir = tmpdir("race");
    let coord = Arc::new(Coordinator::with_config(durable_config(&dir)));
    let lines = [LAYOUTS[0], LAYOUTS[1]];
    let mut sids = Vec::new();
    for line in lines {
        let sid = coord.open(JobSpec::parse_line(0, line).unwrap()).unwrap().sid;
        // durable but cadence-free: only the sweeps write
        coord.persist(sid, Some(0), Some(0)).unwrap();
        sids.push(sid);
    }
    let steppers: Vec<_> = sids
        .iter()
        .map(|&sid| {
            let coord = Arc::clone(&coord);
            std::thread::spawn(move || {
                for _ in 0..6 {
                    coord.step(sid, 2).unwrap();
                }
            })
        })
        .collect();
    for _ in 0..20 {
        let (written, _bytes) = coord.checkpoint_all();
        assert_eq!(written, 2, "a session dropped out of the sweep");
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    for h in steppers {
        h.join().unwrap();
    }
    // one quiescent sweep so the newest record sits at 12 steps, then
    // "crash" without closing anything
    coord.checkpoint_all();
    drop(coord);

    let coord = Coordinator::with_config(durable_config(&dir));
    let report = coord.recovery().expect("recovery report");
    assert_eq!(report.recovered.len(), 2, "{report:?}");
    assert!(report.skipped.is_empty(), "{report:?}");
    for (line, &sid) in lines.iter().zip(&sids) {
        let closed = coord.close(sid).unwrap();
        assert_eq!(closed.steps_done, 12, "layout {line}");
        assert_eq!(closed.state_hash, twin_hash(line, 12), "layout {line}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_with_persists_on_eof_and_recovers_over_the_protocol() {
    let dir = tmpdir("proto");
    let want = format!("{:#018x}", twin_hash(LAYOUTS[3], 6));

    // first serve: open, arm durability, step, EOF — serve_with
    // checkpoints durable sessions on the way out
    let coord = Coordinator::with_config(durable_config(&dir));
    let script = format!("open {}\npersist 1 steps=2\nstep 1 3\n", LAYOUTS[3]);
    let mut out = Vec::new();
    serve_with(&coord, script.as_bytes(), &mut out).unwrap();
    let out = String::from_utf8(out).unwrap();
    assert!(!out.contains("ERR"), "{out}");
    assert!(out.lines().any(|l| l.starts_with("PERSIST 1 ")), "{out}");
    drop(coord);

    // second serve on the same dir: recover, finish, close
    let coord = Coordinator::with_config(durable_config(&dir));
    let mut out = Vec::new();
    serve_with(&coord, "recover\nstep 1 3\nclose 1\n".as_bytes(), &mut out).unwrap();
    let out = String::from_utf8(out).unwrap();
    assert!(!out.contains("ERR"), "{out}");
    let recover = out.lines().find(|l| l.starts_with("RECOVER ")).unwrap();
    assert!(recover.contains("recovered=1"), "{out}");
    assert!(recover.contains("skipped=0"), "{out}");
    let closed = out.lines().find(|l| l.starts_with("CLOSED 1")).unwrap();
    assert!(closed.contains("steps=6"), "{out}");
    assert!(closed.contains(&format!("hash={want}")), "{out}");
    // close removed the durable session's checkpoint: a third start
    // finds an empty store
    drop(coord);
    let coord = Coordinator::with_config(durable_config(&dir));
    let report = coord.recovery().expect("recovery report");
    assert!(report.recovered.is_empty(), "{report:?}");
    let _ = std::fs::remove_dir_all(&dir);
}
