//! Cross-module integration: coordinator + engines + memory accounting,
//! exercised the way the CLI does.

use squeeze::ca::{EngineKind, Rule};
use squeeze::coordinator::{execute_job, service, JobSpec, Scheduler};
use squeeze::fractal::catalog;
use squeeze::memory;

fn job(engine: EngineKind, r: u32, steps: u32) -> JobSpec {
    JobSpec {
        id: 0,
        fractal: "sierpinski-triangle".into(),
        engine,
        r,
        steps,
        density: 0.4,
        seed: 42,
        rule: Rule::game_of_life(),
        workers: 2,
        ..JobSpec::default()
    }
}

#[test]
fn the_three_paper_approaches_agree_over_long_runs() {
    let bb = execute_job(&job(EngineKind::Bb, 6, 30)).unwrap();
    let bbb = execute_job(&job(EngineKind::PackedBb, 6, 30)).unwrap();
    let lam = execute_job(&job(EngineKind::Lambda, 6, 30)).unwrap();
    let sq = execute_job(&job(EngineKind::Squeeze { rho: 1, tensor: false }, 6, 30)).unwrap();
    let sqb = execute_job(&job(EngineKind::Squeeze { rho: 8, tensor: false }, 6, 30)).unwrap();
    assert_eq!(bb.state_hash, bbb.state_hash);
    assert_eq!(bb.state_hash, lam.state_hash);
    assert_eq!(bb.state_hash, sq.state_hash);
    assert_eq!(bb.state_hash, sqb.state_hash);
    assert_eq!(bb.population, sq.population);
    // the bit-planar BB twin carries the embedding at an eighth the bytes
    assert!(
        bbb.memory_bytes < bb.memory_bytes / 4,
        "bb-bits {} vs bb {}",
        bbb.memory_bytes,
        bb.memory_bytes
    );
}

#[test]
fn memory_ordering_matches_paper_p2() {
    // BB ≥ λ(ω) >> Squeeze, and Squeeze grows with ρ (micro-fractal
    // overhead) — Table 2's qualitative content, measured on live engines.
    let r = 10;
    let bb = execute_job(&job(EngineKind::Bb, r, 1)).unwrap();
    let lam = execute_job(&job(EngineKind::Lambda, r, 1)).unwrap();
    let sq1 = execute_job(&job(EngineKind::Squeeze { rho: 1, tensor: false }, r, 1)).unwrap();
    let sq16 = execute_job(&job(EngineKind::Squeeze { rho: 16, tensor: false }, r, 1)).unwrap();
    assert!(bb.memory_bytes >= lam.memory_bytes);
    assert!(lam.memory_bytes > sq16.memory_bytes);
    assert!(sq16.memory_bytes > sq1.memory_bytes);
    // measured engine (u8 cells, 2 buffers + tiny λ tables / the block
    // adjacency) matches the accounting model to within table overhead
    let spec = catalog::sierpinski_triangle();
    let model1 = 2 * memory::squeeze_bytes(&spec, r, 1, 1).unwrap();
    assert!(sq1.memory_bytes >= model1 && sq1.memory_bytes < model1 + model1 / 10);
    let model16 = 2 * memory::squeeze_bytes(&spec, r, 16, 1).unwrap();
    assert!(
        sq16.memory_bytes >= model16 && sq16.memory_bytes <= model16 + model16 / 4,
        "block engine memory {} vs model {model16}",
        sq16.memory_bytes
    );
}

#[test]
fn packed_backend_agrees_and_undercuts_byte_memory() {
    let r = 10;
    let byte = execute_job(&job(EngineKind::Squeeze { rho: 16, tensor: false }, r, 3)).unwrap();
    let packed = execute_job(&job(EngineKind::PackedSqueeze { rho: 16 }, r, 3)).unwrap();
    let packed_sharded =
        execute_job(&job(EngineKind::PackedShardedSqueeze { rho: 16, shards: 4 }, r, 3)).unwrap();
    let mma = execute_job(&job(EngineKind::PackedMmaSqueeze { rho: 16 }, r, 3)).unwrap();
    assert_eq!(byte.state_hash, packed.state_hash);
    assert_eq!(byte.state_hash, packed_sharded.state_hash);
    assert_eq!(byte.state_hash, mma.state_hash);
    assert_eq!(byte.population, packed.population);
    // 1-bit cells: at ρ=16 the packed state is half the byte state
    assert!(
        packed.memory_bytes < byte.memory_bytes,
        "packed {} vs byte {}",
        packed.memory_bytes,
        byte.memory_bytes
    );
    // measured engine (2 packed buffers + the shared adjacency) matches
    // the accounting model to within table overhead
    let spec = catalog::sierpinski_triangle();
    let model = 2 * memory::packed_squeeze_bytes(&spec, r, 16).unwrap();
    assert!(
        packed.memory_bytes >= model && packed.memory_bytes <= model + model / 2,
        "packed engine memory {} vs model {model}",
        packed.memory_bytes
    );
}

#[test]
fn scheduler_handles_a_mixed_batch() {
    let sched = Scheduler::start(3);
    for (i, kind) in [
        EngineKind::Bb,
        EngineKind::Lambda,
        EngineKind::Squeeze { rho: 1, tensor: false },
        EngineKind::Squeeze { rho: 2, tensor: false },
        EngineKind::Squeeze { rho: 4, tensor: true },
    ]
    .into_iter()
    .enumerate()
    {
        let mut j = job(kind, 4, 4);
        j.id = i as u64;
        sched.submit(j);
    }
    let results = sched.shutdown();
    assert_eq!(results.len(), 5);
    let hashes: Vec<u64> = results
        .iter()
        .map(|r| r.as_ref().unwrap().state_hash)
        .collect();
    assert!(hashes.windows(2).all(|w| w[0] == w[1]));
}

#[test]
fn service_session_end_to_end() {
    let script = "\
engine=bb r=5 steps=10 workers=2
engine=lambda r=5 steps=10 workers=2
engine=squeeze:4 r=5 steps=10 workers=2
metrics
quit
";
    let mut out = Vec::new();
    service::serve(script.as_bytes(), &mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    let rows: Vec<&str> = text
        .lines()
        .filter(|l| !l.starts_with('#') && !l.is_empty())
        .collect();
    assert_eq!(rows.len(), 3, "{text}");
    let hashes: Vec<&str> = rows.iter().map(|r| r.split('\t').last().unwrap()).collect();
    assert!(hashes.windows(2).all(|w| w[0] == w[1]), "{text}");
    assert!(text.contains("completed=3"), "{text}");
}

#[test]
fn tensor_engine_results_match_scalar_through_coordinator() {
    let scalar =
        execute_job(&job(EngineKind::Squeeze { rho: 4, tensor: false }, 5, 12)).unwrap();
    let tensor =
        execute_job(&job(EngineKind::Squeeze { rho: 4, tensor: true }, 5, 12)).unwrap();
    assert_eq!(scalar.state_hash, tensor.state_hash);
}

#[test]
fn all_catalog_fractals_run_through_coordinator() {
    for fractal in ["vicsek", "sierpinski-carpet", "empty-bottles", "chandelier"] {
        let mut j = job(EngineKind::Squeeze { rho: 3, tensor: false }, 3, 5);
        j.fractal = fractal.into();
        let sq = execute_job(&j).unwrap();
        let mut jb = job(EngineKind::Bb, 3, 5);
        jb.fractal = fractal.into();
        let bb = execute_job(&jb).unwrap();
        assert_eq!(sq.state_hash, bb.state_hash, "{fractal}");
    }
}
