//! Cross-layer golden-vector tests: the Rust maps/engines must agree
//! exactly with the Python (JAX/Pallas) layer. Vectors are written by
//! `python/compile/aot.py` into `artifacts/`; run `make artifacts` first.

use squeeze::ca::{build, EngineConfig, EngineKind, Rule};
use squeeze::fractal::{catalog, Coord};
use squeeze::maps::{lambda, nu, MapCtx};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.tsv").exists() {
        eprintln!(
            "skipped: artifacts/ not present at {} (run `make artifacts` to \
             generate the Python golden vectors)",
            dir.display()
        );
        return None;
    }
    Some(dir)
}

/// Golden vectors are optional build artifacts: absent → skip cleanly,
/// present-but-unreadable for the requested name → also skip (another
/// artifact set may have been built), numeric garbage → fail loudly.
fn load_rows(name: &str) -> Option<Vec<Vec<i64>>> {
    let dir = artifacts_dir()?;
    let path = dir.join(name);
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!("skipped: golden vector {} not in artifact set", path.display());
        return None;
    };
    Some(
        text.lines()
            .filter(|l| !l.starts_with('#') && !l.trim().is_empty())
            .map(|l| {
                l.split_whitespace()
                    .map(|t| t.parse::<i64>().expect("golden vector numeric"))
                    .collect()
            })
            .collect(),
    )
}

#[test]
fn lambda_matches_python_golden() {
    let Some(rows) = load_rows("golden_lambda_sierpinski-triangle_r8.tsv") else {
        eprintln!("skipped: artifacts not built");
        return;
    };
    let spec = catalog::sierpinski_triangle();
    let ctx = MapCtx::new(&spec, 8);
    for row in rows {
        let (_idx, cx, cy, ex, ey) = (row[0], row[1], row[2], row[3], row[4]);
        let e = lambda(&ctx, Coord::new(cx as u32, cy as u32));
        assert_eq!(
            (e.x as i64, e.y as i64),
            (ex, ey),
            "λ({cx},{cy}) diverges from python"
        );
    }
}

#[test]
fn nu_matches_python_golden() {
    let Some(rows) = load_rows("golden_nu_sierpinski-triangle_r8.tsv") else {
        eprintln!("skipped: artifacts not built");
        return;
    };
    let spec = catalog::sierpinski_triangle();
    let ctx = MapCtx::new(&spec, 8);
    for row in rows {
        let (ex, ey, valid, cx, cy) = (row[0], row[1], row[2] != 0, row[3], row[4]);
        let got = nu(&ctx, Coord::new(ex as u32, ey as u32));
        match (valid, got) {
            (true, Some(c)) => assert_eq!(
                (c.x as i64, c.y as i64),
                (cx, cy),
                "ν({ex},{ey}) diverges from python"
            ),
            (false, None) => {}
            (want, got) => panic!("ν({ex},{ey}) validity: python={want} rust={got:?}"),
        }
    }
}

#[test]
fn step_populations_match_python_golden() {
    let Some(rows) = load_rows("golden_step_sierpinski-triangle_r5.tsv") else {
        eprintln!("skipped: artifacts not built");
        return;
    };
    let spec = catalog::sierpinski_triangle();
    let mut engine = build(
        &spec,
        &EngineConfig {
            kind: EngineKind::Squeeze { rho: 1, tensor: false },
            r: 5,
            rule: Rule::game_of_life(),
            density: 0.4,
            seed: 42,
            workers: 2,
            ..Default::default()
        },
    )
    .expect("valid engine config");
    assert_eq!(engine.population(), rows[0][1] as u64, "seed state");
    for row in &rows[1..] {
        engine.step();
        assert_eq!(
            engine.population(),
            row[1] as u64,
            "population after step {}",
            row[0]
        );
    }
}
