//! PJRT integration: load the AOT artifacts, execute them, and verify
//! bit-exact agreement with the native Rust engines (the three-layer
//! contract). Skips gracefully when artifacts are not built.

use squeeze::ca::{build, EngineConfig, EngineKind, Rule};
use squeeze::fractal::{catalog, Coord};
use squeeze::maps::{nu, MapCtx};
use squeeze::runtime::Runtime;

fn open_runtime() -> Option<Runtime> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipped: built without the `pjrt` feature (stub runtime cannot execute)");
        return None;
    }
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.tsv").exists() {
        eprintln!("skipped: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(dir).expect("runtime"))
}

fn seeded_state(cells: u64) -> Vec<f32> {
    (0..cells)
        .map(|i| {
            if squeeze::ca::engine::seeded_alive(42, i, 0.4) {
                1.0
            } else {
                0.0
            }
        })
        .collect()
}

#[test]
fn squeeze_artifact_matches_native_engine() {
    let Some(mut rt) = open_runtime() else { return };
    let name = "squeeze_sierpinski-triangle_r4";
    let meta = rt.meta(name).expect("artifact in manifest").clone();
    let state = seeded_state(meta.rows * meta.cols);
    let out = rt.run_steps(name, &state, 5).expect("execute");

    let spec = catalog::by_name(&meta.fractal).unwrap();
    let mut engine = build(
        &spec,
        &EngineConfig {
            kind: EngineKind::Squeeze { rho: 1, tensor: false },
            r: meta.r,
            rule: Rule::game_of_life(),
            density: 0.4,
            seed: 42,
            workers: 2,
            ..Default::default()
        },
    )
    .expect("valid engine config");
    for _ in 0..5 {
        engine.step();
    }
    for idx in 0..meta.rows * meta.cols {
        assert_eq!(
            out[idx as usize] > 0.5,
            engine.cell(idx) == 1,
            "mismatch at compact idx {idx}"
        );
    }
}

#[test]
fn fused_multistep_artifact_equals_repeated_single_steps() {
    let Some(mut rt) = open_runtime() else { return };
    let single = "squeeze_sierpinski-triangle_r6";
    let fused = "squeeze_sierpinski-triangle_r6_x10";
    let meta = rt.meta(single).expect("artifact").clone();
    let state = seeded_state(meta.rows * meta.cols);
    let a = rt.run_steps(single, &state, 10).expect("single x10");
    let b = rt.run_steps(fused, &state, 1).expect("fused x10");
    assert_eq!(a, b, "fori_loop fusion must not change results");
}

#[test]
fn bb_artifact_matches_native_bb() {
    let Some(mut rt) = open_runtime() else { return };
    let name = "bb_sierpinski-triangle_r4";
    let meta = rt.meta(name).expect("artifact").clone();
    let spec = catalog::by_name(&meta.fractal).unwrap();
    // scatter the canonical seed into expanded space
    let ctx = MapCtx::new(&spec, meta.r);
    let n = meta.rows;
    let mut grid = vec![0f32; (n * n) as usize];
    for idx in 0..spec.cells(meta.r) {
        if squeeze::ca::engine::seeded_alive(42, idx, 0.4) {
            let e = squeeze::maps::lambda_linear(&ctx, idx);
            grid[(e.y as u64 * n + e.x as u64) as usize] = 1.0;
        }
    }
    let out = rt.run_steps(name, &grid, 4).expect("execute");

    let mut engine = build(
        &spec,
        &EngineConfig {
            kind: EngineKind::Bb,
            r: meta.r,
            rule: Rule::game_of_life(),
            density: 0.4,
            seed: 42,
            workers: 2,
            ..Default::default()
        },
    )
    .expect("valid engine config");
    for _ in 0..4 {
        engine.step();
    }
    // compare in canonical compact order
    for idx in 0..spec.cells(meta.r) {
        let e = squeeze::maps::lambda_linear(&ctx, idx);
        let pjrt = out[(e.y as u64 * n + e.x as u64) as usize] > 0.5;
        assert_eq!(pjrt, engine.cell(idx) == 1, "mismatch at {idx}");
    }
}

#[test]
fn nu_probe_artifact_matches_rust_map() {
    let Some(mut rt) = open_runtime() else { return };
    let name = "nu_probe_sierpinski-triangle_r8_b1024";
    let meta = rt.meta(name).expect("artifact").clone();
    let spec = catalog::by_name(&meta.fractal).unwrap();
    let ctx = MapCtx::new(&spec, meta.r);
    let mut prng = squeeze::util::prng::Prng::new(99);
    let pts: Vec<(f32, f32)> = (0..256)
        .map(|_| {
            (
                prng.below(ctx.n as u64) as f32,
                prng.below(ctx.n as u64) as f32,
            )
        })
        .collect();
    let got = rt.run_nu_probe(name, &pts).expect("probe");
    for (i, &(x, y)) in pts.iter().enumerate() {
        let want = nu(&ctx, Coord::new(x as u32, y as u32)).map(|c| (c.x, c.y));
        assert_eq!(got[i], want, "ν({x},{y})");
    }
}

#[test]
fn vicsek_artifact_cross_fractal() {
    let Some(mut rt) = open_runtime() else { return };
    let name = "squeeze_vicsek_r4";
    let meta = rt.meta(name).expect("artifact").clone();
    let state = seeded_state(meta.rows * meta.cols);
    let out = rt.run_steps(name, &state, 3).expect("execute");
    let spec = catalog::by_name("vicsek").unwrap();
    let mut engine = build(
        &spec,
        &EngineConfig {
            kind: EngineKind::Squeeze { rho: 1, tensor: false },
            r: 4,
            rule: Rule::game_of_life(),
            density: 0.4,
            seed: 42,
            workers: 2,
            ..Default::default()
        },
    )
    .expect("valid engine config");
    for _ in 0..3 {
        engine.step();
    }
    for idx in 0..meta.rows * meta.cols {
        assert_eq!(out[idx as usize] > 0.5, engine.cell(idx) == 1, "idx {idx}");
    }
}
