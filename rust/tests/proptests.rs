//! Property-based tests over the core invariants, using the in-repo
//! mini-proptest framework (`util::proptest`): randomized fractals,
//! levels and coordinates with shrinking on failure.

use squeeze::ca::{
    build, ByteBackend, EngineConfig, EngineKind, PackedBackend, RimSegs, Rule, StateBackend,
};
use squeeze::fractal::{catalog, Coord, MOORE};
use squeeze::maps::cache::{BlockMaps, MapCache, NO_BLOCK};
use squeeze::maps::mma::{lambda_a_fragment, lambda_batch_mma, nu_a_fragment, nu_batch_mma};
use squeeze::maps::{lambda, nu, on_fractal, BlockCtx, MapCtx};
use squeeze::tcu::MmaMode;
use squeeze::util::proptest::Runner;

fn specs() -> Vec<squeeze::fractal::FractalSpec> {
    catalog::all()
}

#[test]
fn prop_nu_inverts_lambda() {
    let all = specs();
    Runner::new("nu∘lambda=id", 0xA1).run(4000, |g| {
        let spec = g.choose(&all);
        let r = g.u32(0, 12);
        let ctx = MapCtx::new(spec, r);
        let idx = g.u64(0, ctx.compact.area() - 1);
        let c = Coord::from_linear(idx, ctx.compact.w);
        let e = lambda(&ctx, c);
        Runner::check(
            nu(&ctx, e) == Some(c),
            &format!("{} r={r} c={c} e={e}", spec.name),
        )
    });
}

#[test]
fn prop_nu_membership_equals_spec_contains() {
    let all = specs();
    Runner::new("nu-validity=membership", 0xA2).run(3000, |g| {
        let spec = g.choose(&all);
        let r = g.u32(1, 8);
        let ctx = MapCtx::new(spec, r);
        let x = g.u32(0, ctx.n * 2); // include out-of-range
        let y = g.u32(0, ctx.n * 2);
        let e = Coord::new(x, y);
        let via_nu = nu(&ctx, e).is_some();
        let via_spec = spec.contains(e, r);
        Runner::check(
            via_nu == via_spec && via_nu == on_fractal(&ctx, e),
            &format!("{} r={r} e={e}: nu={via_nu} spec={via_spec}", spec.name),
        )
    });
}

#[test]
fn prop_lambda_image_lies_on_fractal() {
    let all = specs();
    Runner::new("lambda-image-on-fractal", 0xA3).run(3000, |g| {
        let spec = g.choose(&all);
        let r = g.u32(0, 10);
        let ctx = MapCtx::new(spec, r);
        let idx = g.u64(0, ctx.compact.area() - 1);
        let e = lambda(&ctx, Coord::from_linear(idx, ctx.compact.w));
        Runner::check(
            spec.contains(e, r),
            &format!("{} r={r} idx={idx} -> {e} off fractal", spec.name),
        )
    });
}

#[test]
fn prop_mma_encoding_matches_scalar_maps() {
    let all = specs();
    Runner::new("mma=scalar", 0xA4).run(600, |g| {
        let spec = g.choose(&all);
        // stay inside the FP16 exactness envelope (maps::mma documents it;
        // outside it the paper's FP16 configuration is genuinely unsound,
        // pinned by fp16_exactness_cliff_at_thread_level_r16)
        let r_max = squeeze::maps::mma::fp16_exact_max_level(spec).min(10);
        let r = g.u32(1, r_max);
        let ctx = MapCtx::new(spec, r);
        let nu_a = nu_a_fragment(&ctx);
        let la = lambda_a_fragment(&ctx);
        // batch of up to 8 compact points
        let count = g.usize(1, 8);
        let pts: Vec<Coord> = (0..count)
            .map(|_| Coord::from_linear(g.u64(0, ctx.compact.area() - 1), ctx.compact.w))
            .collect();
        let lam_mma = lambda_batch_mma(&ctx, &la, &pts, MmaMode::Fp16);
        for (i, &c) in pts.iter().enumerate() {
            let want = lambda(&ctx, c);
            if lam_mma[i] != want {
                return Err(format!(
                    "{} r={r} λ-mma {c}: {} != {want}",
                    spec.name, lam_mma[i]
                ));
            }
        }
        let expanded: Vec<Coord> = pts.iter().map(|&c| lambda(&ctx, c)).collect();
        let nu_mma = nu_batch_mma(&ctx, &nu_a, &expanded, MmaMode::Fp16);
        for (i, &c) in pts.iter().enumerate() {
            if nu_mma[i] != Some(c) {
                return Err(format!("{} r={r} ν-mma: {:?} != {c}", spec.name, nu_mma[i]));
            }
        }
        Ok(())
    });
}

/// Exhaustive two-way roundtrip at levels 1..=5 for every catalog
/// fractal, through both the fresh maps and the shared cache:
/// `ν(λ(ω)) = ω` for all compact coordinates, and `λ(ν(p)) = p` for all
/// occupied expanded coordinates.
#[test]
fn roundtrips_hold_exhaustively_at_levels_1_to_5_with_and_without_cache() {
    let cache = MapCache::new();
    for spec in catalog::all() {
        for r in 1..=5 {
            let fresh = MapCtx::new(&spec, r);
            let cached = cache.thread_maps(&spec, r);
            // ν ∘ λ = id on compact space (fresh and cached λ agree)
            for idx in 0..fresh.compact.area() {
                let c = Coord::from_linear(idx, fresh.compact.w);
                let e = lambda(&fresh, c);
                assert_eq!(
                    cached.lambda_table.eval(c),
                    e,
                    "{} r={r} {c}: cached λ != fresh λ",
                    spec.name
                );
                assert_eq!(nu(&fresh, e), Some(c), "{} r={r} {c}: ν(λ(ω)) != ω", spec.name);
                assert_eq!(nu(&cached.ctx, e), Some(c), "{} r={r} {c} (cached ν)", spec.name);
            }
            // λ ∘ ν = id on occupied expanded space
            let n = fresh.n;
            for y in 0..n {
                for x in 0..n {
                    let p = Coord::new(x, y);
                    if let Some(c) = nu(&fresh, p) {
                        assert_eq!(
                            lambda(&fresh, c),
                            p,
                            "{} r={r} {p}: λ(ν(p)) != p",
                            spec.name
                        );
                        assert_eq!(lambda(&cached.ctx, c), p, "{} r={r} {p} (cached λ)", spec.name);
                    }
                }
            }
        }
    }
    // 5 fractals × 5 levels, each looked up exactly once
    assert_eq!(cache.stats().misses, 25);
}

#[test]
fn prop_cached_maps_match_fresh_evaluation() {
    let all = specs();
    let cache = MapCache::new();
    Runner::new("cache=fresh", 0xA8).run(2000, |g| {
        let spec = g.choose(&all);
        let r = g.u32(1, 5);
        let cached = cache.thread_maps(spec, r);
        let fresh = MapCtx::new(spec, r);
        let idx = g.u64(0, fresh.compact.area() - 1);
        let c = Coord::from_linear(idx, fresh.compact.w);
        let e = lambda(&fresh, c);
        Runner::check(
            cached.lambda_table.eval(c) == e
                && nu(&cached.ctx, e) == Some(c)
                && nu(&fresh, e) == Some(c),
            &format!("{} r={r} c={c} e={e}", spec.name),
        )
    });
    let stats = cache.stats();
    assert!(stats.hits > 0 && stats.misses <= 25, "{stats:?}");
}

#[test]
fn prop_block_adjacency_table_matches_direct_maps() {
    let all = specs();
    Runner::new("block-adjacency=maps", 0xA9).run(150, |g| {
        let spec = g.choose(&all);
        let r = g.u32(2, 5);
        let intra = g.u32(0, 2.min(r));
        let rho = spec.s.pow(intra);
        let maps = BlockMaps::build(spec, r, rho, None, 2).expect("valid rho");
        let coarse = &maps.block.coarse;
        let tile = rho as u64 * rho as u64;
        let bidx = g.u64(0, maps.block.blocks() - 1);
        let dir = g.usize(0, 7);
        let (dx, dy) = MOORE[dir];
        let eb = lambda(coarse, Coord::from_linear(bidx, coarse.compact.w));
        let want = eb
            .offset(dx, dy)
            .and_then(|ne| nu(coarse, ne))
            .map(|cbn| cbn.linear(coarse.compact.w) * tile)
            .unwrap_or(NO_BLOCK);
        Runner::check(
            maps.neighbors_of(bidx)[dir] == want,
            &format!("{} r={r} rho={rho} block={bidx} dir={dir}", spec.name),
        )
    });
}

#[test]
fn prop_block_storage_is_a_bijection() {
    let all = specs();
    Runner::new("block-storage-bijection", 0xA5).run(400, |g| {
        let spec = g.choose(&all);
        let r = g.u32(2, 7);
        let intra = g.u32(0, 2.min(r));
        let rho = spec.s.pow(intra);
        let b = BlockCtx::new(spec, r, rho).expect("valid rho");
        let full = MapCtx::new(spec, r);
        let idx = g.u64(0, full.compact.area() - 1);
        let e = lambda(&full, Coord::from_linear(idx, full.compact.w));
        let slot = b
            .storage_index(e)
            .ok_or_else(|| format!("{} rho={rho} fractal cell {e} has no slot", spec.name))?;
        Runner::check(
            slot < b.stored_cells() && b.expanded_of_slot(slot) == e,
            &format!("{} r={r} rho={rho} e={e} slot={slot}", spec.name),
        )
    });
}

/// One rim pack→unpack round-trip at a random direction mask: packing
/// the rim of a random tile and unpacking it into a scrambled
/// destination must reproduce exactly the rim cells and leave every
/// other cell of the destination untouched.
fn rim_roundtrip_case<B: StateBackend>(
    block: &BlockCtx,
    g: &mut squeeze::util::proptest::Gen,
) -> Result<(), String> {
    let backend = B::new(block);
    let rho = block.rho;
    let tile_cells = rho as u64 * rho as u64;
    let dirs = g.u64(0, 255) as u8;
    let segs = RimSegs::from_dirs(rho, dirs);
    // random source tile (only fractal cells alive) + scrambled dst
    let mut src = vec![B::Unit::default(); backend.units_per_tile() as usize];
    let mut dst = vec![B::Unit::default(); backend.units_per_tile() as usize];
    for iy in 0..rho {
        for ix in 0..rho {
            let slot = (iy * rho + ix) as u64;
            if block.intra_on_fractal(ix, iy) && g.bool() {
                backend.set_cell(&mut src, slot);
            }
            if g.bool() {
                backend.set_cell(&mut dst, slot);
            }
        }
    }
    let before: Vec<u8> = (0..tile_cells).map(|s| backend.get_cell(&dst, s)).collect();
    let mut stage = vec![B::Unit::default(); backend.rim_units(&segs) as usize];
    backend.pack_rim(&src, 0, &segs, &mut stage);
    backend.unpack_rim(&stage, &mut dst, 0, &segs);
    // which cells are rim cells?
    let mut in_rim = vec![false; tile_cells as usize];
    for &y in &segs.rows {
        for x in 0..rho {
            in_rim[(y * rho + x) as usize] = true;
        }
    }
    for &(x, y0, y1) in &segs.cols {
        for y in y0..y1 {
            in_rim[(y * rho + x) as usize] = true;
        }
    }
    for &(x, y) in &segs.cells {
        in_rim[(y * rho + x) as usize] = true;
    }
    for slot in 0..tile_cells {
        let got = backend.get_cell(&dst, slot);
        let want = if in_rim[slot as usize] {
            backend.get_cell(&src, slot)
        } else {
            before[slot as usize]
        };
        if got != want {
            return Err(format!(
                "rho={rho} dirs={dirs:#010b} slot={slot}: got {got} want {want} (rim={})",
                in_rim[slot as usize]
            ));
        }
    }
    Ok(())
}

#[test]
fn prop_rim_pack_unpack_round_trips_byte_and_packed() {
    // the satellite matrix: ρ ∈ {8, 64, 81, 128} covers single-word
    // rows, exact 64-bit rows, ragged s=3 multi-word rows, and
    // power-of-two multi-word rows — over both storage units
    let tri = catalog::sierpinski_triangle();
    let vic = catalog::vicsek();
    let blocks: Vec<BlockCtx> = vec![
        BlockCtx::new(&tri, 3, 8).unwrap(),
        BlockCtx::new(&tri, 6, 64).unwrap(),
        BlockCtx::new(&vic, 4, 81).unwrap(),
        BlockCtx::new(&tri, 7, 128).unwrap(),
    ];
    Runner::new("rim-roundtrip", 0xAB).run(48, |g| {
        let block = g.choose(&blocks);
        rim_roundtrip_case::<ByteBackend>(block, g)?;
        rim_roundtrip_case::<PackedBackend>(block, g)
    });
}

#[test]
fn prop_sharded_modes_agree_with_single_engine() {
    // overlap on/off × compaction on/off × byte/packed, random shard
    // counts: all bit-identical to the single block engine per run
    let all = specs();
    Runner::new("sharded-modes-agree", 0xAC).run(20, |g| {
        let spec = g.choose(&all);
        let r = g.u32(2, 4);
        let steps = g.u32(1, 4);
        let seed = g.u64(0, u64::MAX / 2);
        let rho = spec.s;
        let shards = g.u32(1, 5);
        let overlap = g.bool();
        let compact = g.bool();
        let packed = g.bool();
        let single = {
            let mut e = build(
                spec,
                &EngineConfig {
                    kind: EngineKind::Squeeze { rho, tensor: false },
                    r,
                    seed,
                    workers: 2,
                    ..Default::default()
                },
            )
            .expect("valid engine config");
            for _ in 0..steps {
                e.step();
            }
            e.state_hash()
        };
        let kind = if packed {
            EngineKind::PackedShardedSqueeze { rho, shards }
        } else {
            EngineKind::ShardedSqueeze { rho, shards }
        };
        let mut e = build(
            spec,
            &EngineConfig {
                kind,
                r,
                seed,
                workers: g.usize(1, 4),
                overlap,
                compact,
                ..Default::default()
            },
        )
        .expect("valid engine config");
        for _ in 0..steps {
            e.step();
        }
        Runner::check(
            e.state_hash() == single,
            &format!(
                "{} r={r} steps={steps} shards={shards} overlap={overlap} \
                 compact={compact} packed={packed}",
                spec.name
            ),
        )
    });
}

#[test]
fn prop_engines_agree_after_random_runs() {
    let all = specs();
    Runner::new("engines-agree", 0xA6).run(25, |g| {
        let spec = g.choose(&all);
        let r = g.u32(2, 4);
        let steps = g.u32(1, 5);
        let seed = g.u64(0, u64::MAX / 2);
        let density_pct = g.u64(10, 90);
        let rho = spec.s.pow(g.u32(0, 1));
        let mut hashes = Vec::new();
        for kind in [
            EngineKind::Bb,
            EngineKind::Lambda,
            EngineKind::Squeeze { rho: 1, tensor: false },
            EngineKind::Squeeze { rho, tensor: false },
            EngineKind::PackedSqueeze { rho },
            EngineKind::PackedShardedSqueeze { rho, shards: 3 },
        ] {
            let mut e = build(
                spec,
                &EngineConfig {
                    kind,
                    r,
                    rule: Rule::game_of_life(),
                    density: density_pct as f64 / 100.0,
                    seed,
                    workers: 2,
                    ..Default::default()
                },
            )
            .expect("valid engine config");
            for _ in 0..steps {
                e.step();
            }
            hashes.push((e.name(), e.state_hash()));
        }
        let first = hashes[0].1;
        Runner::check(
            hashes.iter().all(|(_, h)| *h == first),
            &format!(
                "{} r={r} steps={steps} seed={seed} d={density_pct}%: {hashes:?}",
                spec.name
            ),
        )
    });
}

#[test]
fn prop_population_conserved_under_still_life_rule() {
    // Rule B/S012345678: every live cell survives, nothing is born —
    // population must stay exactly constant on any fractal.
    let all = specs();
    Runner::new("still-life-rule", 0xA7).run(50, |g| {
        let spec = g.choose(&all);
        let r = g.u32(2, 5);
        let rule = Rule::parse("B/S012345678").unwrap();
        let mut e = build(
            spec,
            &EngineConfig {
                kind: EngineKind::Squeeze { rho: 1, tensor: false },
                r,
                rule,
                density: 0.5,
                seed: g.u64(0, 1 << 40),
                workers: 1,
                ..Default::default()
            },
        )
        .expect("valid engine config");
        let before = e.population();
        e.step();
        e.step();
        Runner::check(
            e.population() == before,
            &format!("{} r={r}: {before} -> {}", spec.name, e.population()),
        )
    });
}

#[test]
fn prop_engine_spec_display_parse_round_trips_every_variant() {
    use squeeze::ca::EngineSpec;
    // the one-grammar contract: parse(display(spec)) == spec over every
    // constructible kind, with randomized ρ, shard counts and @hosts=
    // placements (including the rho=1 "bare name" renderings)
    Runner::new("engine-spec-roundtrip", 0xB1).run(2000, |g| {
        let rho = *g.choose(&[1u32, 2, 3, 4, 8, 9, 16, 27, 32, 81, 128, 1024]);
        let shards = g.u32(1, 64);
        let kind = match g.u32(0, 5) {
            0 => EngineKind::Bb,
            1 => EngineKind::Lambda,
            2 => EngineKind::Squeeze { rho, tensor: g.bool() },
            3 => EngineKind::ShardedSqueeze { rho, shards },
            4 => EngineKind::PackedSqueeze { rho },
            _ => EngineKind::PackedShardedSqueeze { rho, shards },
        };
        let hosts = match kind {
            EngineKind::ShardedSqueeze { .. } | EngineKind::PackedShardedSqueeze { .. } => {
                g.u32(1, shards.min(4))
            }
            _ => 1,
        };
        let spec = EngineSpec { kind, hosts };
        let text = spec.to_string();
        Runner::check(
            EngineSpec::parse(&text) == Ok(spec),
            &format!("{kind:?} hosts={hosts} -> {text:?}"),
        )
    });
}

#[test]
fn prop_cluster_route_codec_round_trips_and_rejects_torn_tables() {
    use squeeze::net::{decode_routes, encode_routes};
    use squeeze::shard::HaloRoute;
    Runner::new("route-codec-roundtrip", 0xB4).run(400, |g| {
        let n = g.usize(0, 40);
        let routes: Vec<HaloRoute> = (0..n)
            .map(|_| HaloRoute {
                src_shard: g.usize(0, 4096),
                src_block: g.u64(0, u64::MAX),
                dst_shard: g.usize(0, 4096),
                ghost_slot: g.u64(0, u64::MAX),
                dirs: g.u64(0, 255) as u8,
            })
            .collect();
        let bytes = encode_routes(&routes);
        if decode_routes(&bytes).as_deref() != Ok(&routes[..]) {
            return Err(format!("{n}-route table failed to round-trip"));
        }
        // any strict prefix is a structural error — never a panic
        let cut = g.usize(0, bytes.len() - 1);
        if decode_routes(&bytes[..cut]).is_ok() {
            return Err(format!("truncation to {cut}/{} bytes accepted", bytes.len()));
        }
        let mut padded = bytes;
        padded.push(g.u64(0, 255) as u8);
        Runner::check(decode_routes(&padded).is_err(), "padded route table accepted")
    });
}

#[test]
fn prop_cluster_frames_reject_corruption_without_panicking() {
    use squeeze::net::frame::read_frame;
    use squeeze::net::{Frame, SegKind};
    let kinds = [SegKind::Rim, SegKind::StepHash, SegKind::StepCmd, SegKind::Bye];
    Runner::new("frame-corruption", 0xB5).run(400, |g| {
        let payload: Vec<u8> = (0..g.usize(0, 64)).map(|_| g.u64(0, 255) as u8).collect();
        let f = Frame {
            kind: *g.choose(&kinds),
            step: g.u64(0, u64::MAX),
            src_shard: g.u64(0, u32::MAX as u64) as u32,
            dst_shard: g.u64(0, u32::MAX as u64) as u32,
            payload,
        };
        let wire = f.encode();
        if Frame::decode(&wire).as_ref() != Ok(&f) {
            return Err("frame failed to round-trip".to_string());
        }
        // a random single-bit flip anywhere in the image is always
        // caught (magic/version/kind/len checks or the trailing CRC)
        let mut bad = wire.clone();
        let byte = g.usize(0, bad.len() - 1);
        let bit = g.u32(0, 7);
        bad[byte] ^= 1u8 << bit;
        if Frame::decode(&bad).is_ok() {
            return Err(format!("bit flip at byte {byte} bit {bit} slipped through"));
        }
        // a torn stream read errors cleanly, never panics or blocks
        let cut = g.usize(0, wire.len() - 1);
        Runner::check(
            read_frame(&mut &wire[..cut]).is_err(),
            &format!("truncated stream read to {cut} accepted"),
        )
    });
}

#[test]
fn prop_job_spec_to_line_round_trips_including_promotions() {
    // random valid request lines (engine strings plus the shards=/auto/
    // packed promotions and the sharded-only overlap/compact keys):
    // parse -> to_line -> parse must be the identity on JobSpec
    let all = specs();
    let rules = ["B3/S23", "B36/S23", "B2/S", "B/S012345678", "B1357/S1357"];
    Runner::new("job-line-roundtrip", 0xB2).run(2000, |g| {
        let fractal = g.choose(&all).name.to_string();
        let rho = *g.choose(&[1u32, 2, 4, 8, 16]);
        let shards = g.u32(1, 8);
        let engine = match g.u32(0, 5) {
            0 => "bb".to_string(),
            1 => "lambda".to_string(),
            2 => format!("squeeze:{rho}"),
            3 => format!("squeeze-tcu:{rho}"),
            4 => format!("sharded-squeeze:{rho}:{shards}"),
            _ => format!("squeeze-bits:{rho}:{shards}"),
        };
        let mut line = format!(
            "fractal={fractal} engine={engine} r={} steps={} density=0.{} seed={} rule={} workers={}",
            g.u32(1, 9),
            g.u32(0, 100),
            g.u32(0, 99),
            g.u64(0, u64::MAX),
            g.choose(&rules),
            g.usize(1, 16),
        );
        let sharded = engine.starts_with("sharded-squeeze") || engine.matches(':').count() == 2;
        if sharded {
            if g.bool() {
                line.push_str(&format!(" overlap={}", g.u32(0, 1)));
            }
            if g.bool() {
                line.push_str(&format!(" compact={}", g.u32(0, 1)));
            }
            if g.bool() {
                line.push_str(&format!(" shards=auto:{}", g.u32(1, 8)));
            }
        } else if engine.starts_with("squeeze:") {
            // exercise the promotion keys on scalar squeeze too
            if g.bool() {
                line.push_str(" packed=1");
            }
            if g.bool() {
                line.push_str(&format!(" shards=auto:{}", g.u32(1, 8)));
            }
        }
        let spec = match squeeze::coordinator::JobSpec::parse_line(3, &line) {
            Ok(s) => s,
            Err(e) => return Runner::check(false, &format!("{line:?} failed to parse: {e}")),
        };
        let rendered = spec.to_line();
        let back = squeeze::coordinator::JobSpec::parse_line(3, &rendered);
        Runner::check(
            back.as_ref() == Ok(&spec),
            &format!("{line:?} -> {rendered:?} -> {back:?}"),
        )
    });
}

#[test]
fn prop_snapshot_tokens_round_trip() {
    // the serve-protocol snapshot token is a faithful encoding: parse ∘
    // to_token == id over random specs, steps, hashes and state bitmaps
    let all = specs();
    Runner::new("snapshot-token-roundtrip", 0xB3).run(500, |g| {
        let fractal = g.choose(&all).name.to_string();
        let line = format!(
            "fractal={fractal} engine=squeeze:{} r={} seed={}",
            *g.choose(&[1u32, 2, 4, 16]),
            g.u32(1, 8),
            g.u64(0, u64::MAX)
        );
        let spec = squeeze::coordinator::JobSpec::parse_line(0, &line).unwrap();
        let bits: Vec<u8> = (0..g.usize(0, 64)).map(|_| g.u64(0, 255) as u8).collect();
        let snap = squeeze::coordinator::SessionSnapshot {
            spec,
            steps_done: g.u64(0, u64::MAX),
            state_hash: g.u64(0, u64::MAX),
            bits,
        };
        let token = snap.to_token();
        let back = squeeze::coordinator::SessionSnapshot::parse(&token);
        Runner::check(
            back.as_ref() == Ok(&snap) && !token.contains(char::is_whitespace),
            &format!("{token:.120} -> {back:?}"),
        )
    });
}
