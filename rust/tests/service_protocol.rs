//! In-memory round-trips of the serve protocol — the coordinator's wire
//! contract, exercised exactly the way the `squeeze serve` binary runs
//! it (a `BufRead`/`Write` pair), with no process spawning.
//!
//! Covers: well-formed jobs (TSV shape), malformed `key=value` lines
//! and semantic errors (`ERR` lines that never kill the session),
//! `metrics`, `quit`, the `shards=` job key, and the differential case
//! asserting `sharded-squeeze` is bit-identical to the single-engine
//! `squeeze:<rho>` on every catalog fractal *through the service*.

use squeeze::coordinator::service::serve;
use squeeze::fractal::catalog;

fn run_session(script: &str) -> String {
    let mut out = Vec::new();
    serve(script.as_bytes(), &mut out).expect("in-memory serve cannot fail on io");
    String::from_utf8(out).expect("protocol output is utf-8")
}

/// Data (non-comment, non-error, non-empty) lines of a session
/// transcript — the successful responses.
fn data_lines(out: &str) -> Vec<&str> {
    out.lines()
        .filter(|l| !l.starts_with('#') && !l.starts_with("ERR") && !l.is_empty())
        .collect()
}

/// The state-hash column (last TSV field) of a result row by job id.
fn hash_of<'a>(rows: &[&'a str], id: &str) -> &'a str {
    rows.iter()
        .find(|l| l.split('\t').next() == Some(id))
        .unwrap_or_else(|| panic!("no result row for job {id}"))
        .split('\t')
        .last()
        .expect("rows have columns")
}

#[test]
fn well_formed_jobs_round_trip_with_full_tsv_rows() {
    let out = run_session(
        "engine=squeeze:4 r=4 steps=2 workers=1 seed=3\n\
         engine=bb r=4 steps=2 workers=1 seed=3\n\
         quit\n",
    );
    assert!(out.starts_with("# squeeze coordinator ready"), "{out}");
    let rows = data_lines(&out);
    assert_eq!(rows.len(), 2, "{out}");
    let header_cols = squeeze::coordinator::JobResult::tsv_header()
        .split('\t')
        .count();
    for row in &rows {
        assert_eq!(row.split('\t').count(), header_cols, "{row}");
    }
    assert_eq!(hash_of(&rows, "1"), hash_of(&rows, "2"), "{out}");
}

#[test]
fn malformed_and_semantic_errors_are_err_lines_and_the_session_survives() {
    let out = run_session(
        "this is not key=value\n\
         engine=warp r=4\n\
         volume=11\n\
         engine=squeeze:3 r=4 steps=1 workers=1\n\
         engine=sharded-squeeze:3:2 r=4 steps=1 workers=1\n\
         engine=squeeze:16 r=2 steps=1 workers=1\n\
         fractal=not-a-fractal r=4 steps=1 workers=1\n\
         engine=squeeze:4 r=4 steps=1 workers=1\n\
         quit\n",
    );
    let errs: Vec<&str> = out.lines().filter(|l| l.starts_with("ERR")).collect();
    assert_eq!(errs.len(), 7, "{out}");
    // the ρ-validation satellites: an invalid ρ is a message, not a panic
    assert!(
        errs.iter().any(|e| e.contains("rho=3") && e.contains("power")),
        "{out}"
    );
    assert!(errs.iter().any(|e| e.contains("rho=16")), "{out}");
    // the session kept serving: the final valid job produced a TSV row
    assert_eq!(data_lines(&out).len(), 1, "{out}");
}

#[test]
fn metrics_command_reports_after_mixed_good_and_failed_jobs() {
    let out = run_session(
        "engine=squeeze:4 r=5 steps=1 workers=1\n\
         engine=squeeze:4 r=5 steps=1 workers=1\n\
         engine=squeeze:3 r=5 steps=1 workers=1\n\
         fractal=nope r=5 steps=1 workers=1\n\
         metrics\n\
         quit\n",
    );
    // cache gauges stay consistent under the error paths: two lookups
    // of one key (1 miss + 1 hit), recorded even though later jobs fail
    assert!(out.contains("map_cache=1/2"), "{out}");
    assert!(out.contains("completed=2"), "{out}");
    assert!(out.contains("failed=2"), "{out}");
}

#[test]
fn quit_ends_the_session_before_remaining_lines() {
    let out = run_session("quit\nengine=squeeze:4 r=4 steps=1 workers=1\n");
    assert_eq!(data_lines(&out).len(), 0, "{out}");
    assert!(!out.contains("ERR"), "{out}");
    // the final summary line still prints
    assert!(out.contains("jobs started=0"), "{out}");
}

#[test]
fn sharded_jobs_report_halo_gauges_in_metrics() {
    let out = run_session(
        "engine=sharded-squeeze:4:4 r=5 steps=2 workers=2\n\
         metrics\nquit\n",
    );
    assert_eq!(data_lines(&out).len(), 1, "{out}");
    assert!(out.contains("sharded=1"), "{out}");
    assert!(out.contains("halo="), "{out}");
    assert!(out.contains("imbalance="), "{out}");
}

#[test]
fn shards_key_equals_explicit_sharded_engine_and_single_engine() {
    let out = run_session(
        "engine=squeeze:4 r=5 steps=3 workers=2 seed=9\n\
         engine=squeeze:4 shards=2 r=5 steps=3 workers=2 seed=9\n\
         engine=sharded-squeeze:4:2 r=5 steps=3 workers=2 seed=9\n\
         quit\n",
    );
    let rows = data_lines(&out);
    assert_eq!(rows.len(), 3, "{out}");
    let single = hash_of(&rows, "1");
    assert_eq!(single, hash_of(&rows, "2"), "shards= key diverged: {out}");
    assert_eq!(single, hash_of(&rows, "3"), "explicit sharded diverged: {out}");
}

#[test]
fn packed_engines_match_byte_engines_through_the_service() {
    // squeeze-bits:<rho>, the packed= promotion key, and the packed
    // sharded decomposition must all hash identical to the byte engine
    let out = run_session(
        "engine=squeeze:4 r=5 steps=3 workers=2 seed=9\n\
         engine=squeeze-bits:4 r=5 steps=3 workers=2 seed=9\n\
         engine=squeeze:4 packed=1 r=5 steps=3 workers=2 seed=9\n\
         engine=squeeze-bits:4:3 r=5 steps=3 workers=2 seed=9\n\
         packed=1 shards=3 engine=squeeze:4 r=5 steps=3 workers=2 seed=9\n\
         quit\n",
    );
    assert!(!out.contains("ERR"), "{out}");
    let rows = data_lines(&out);
    assert_eq!(rows.len(), 5, "{out}");
    let byte = hash_of(&rows, "1");
    for id in ["2", "3", "4", "5"] {
        assert_eq!(byte, hash_of(&rows, id), "job {id} diverged: {out}");
    }
    // the packed engine advertises its backend in the engine column
    assert!(out.contains("squeeze-bits-rho4"), "{out}");
    assert!(out.contains("sharded-squeeze-bits-rho4x3"), "{out}");
}

#[test]
fn packed_semantic_errors_are_err_lines() {
    let out = run_session(
        "engine=squeeze-bits:3 r=5 steps=1 workers=1\n\
         engine=squeeze-bits:16:2 r=2 steps=1 workers=1\n\
         engine=bb packed=1 r=4 steps=1 workers=1\n\
         engine=squeeze-bits:4 r=5 steps=1 workers=1\n\
         quit\n",
    );
    let errs: Vec<&str> = out.lines().filter(|l| l.starts_with("ERR")).collect();
    assert_eq!(errs.len(), 3, "{out}");
    assert!(errs.iter().any(|e| e.contains("rho=3")), "{out}");
    assert!(errs.iter().any(|e| e.contains("rho=16")), "{out}");
    assert!(errs.iter().any(|e| e.contains("packed=")), "{out}");
    // the session survived to run the valid packed job
    assert_eq!(data_lines(&out).len(), 1, "{out}");
}

#[test]
fn overlap_compaction_and_auto_shards_keys_round_trip_through_the_service() {
    // every exchange mode (overlap on/off × compaction on/off), the
    // cost-weighted partitioner, and their packed twins must hash
    // identical to the single-engine run — end to end through serve
    let out = run_session(
        "engine=squeeze:4 r=5 steps=3 workers=2 seed=9\n\
         engine=sharded-squeeze:4:3 r=5 steps=3 workers=2 seed=9\n\
         engine=sharded-squeeze:4:3 overlap=0 compact=0 r=5 steps=3 workers=2 seed=9\n\
         engine=sharded-squeeze:4:3 overlap=1 compact=0 r=5 steps=3 workers=2 seed=9\n\
         engine=sharded-squeeze:4:3 overlap=0 compact=1 r=5 steps=3 workers=2 seed=9\n\
         shards=auto:3 engine=squeeze:4 r=5 steps=3 workers=2 seed=9\n\
         packed=1 shards=auto:3 overlap=1 compact=1 engine=squeeze:4 r=5 steps=3 workers=2 seed=9\n\
         quit\n",
    );
    assert!(!out.contains("ERR"), "{out}");
    let rows = data_lines(&out);
    assert_eq!(rows.len(), 7, "{out}");
    let single = hash_of(&rows, "1");
    for id in ["2", "3", "4", "5", "6", "7"] {
        assert_eq!(single, hash_of(&rows, id), "job {id} diverged: {out}");
    }
}

#[test]
fn sharded_metrics_expose_the_compaction_gauge() {
    let out = run_session(
        "engine=sharded-squeeze:4:4 r=5 steps=2 workers=2\n\
         metrics\nquit\n",
    );
    assert!(out.contains("halo_compaction="), "{out}");
    // compaction is on by default and ρ=4 rims are strictly smaller
    // than tiles, so the gauge must read below 1.00
    let ratio: f64 = out
        .split("halo_compaction=")
        .nth(1)
        .and_then(|s| s.split_whitespace().next())
        .expect("gauge present")
        .parse()
        .expect("gauge is a number");
    assert!(ratio > 0.0 && ratio < 1.0, "{out}");
}

#[test]
fn overlap_keys_on_non_sharded_engines_are_err_lines() {
    let out = run_session(
        "engine=squeeze:4 overlap=1 r=5 steps=1 workers=1\n\
         engine=bb compact=0 r=4 steps=1 workers=1\n\
         shards=auto:2 engine=bb r=4 steps=1 workers=1\n\
         engine=sharded-squeeze:4:2 overlap=2 r=5 steps=1 workers=1\n\
         engine=squeeze:4 r=5 steps=1 workers=1\n\
         quit\n",
    );
    let errs: Vec<&str> = out.lines().filter(|l| l.starts_with("ERR")).collect();
    assert_eq!(errs.len(), 4, "{out}");
    assert!(errs.iter().any(|e| e.contains("overlap=")), "{out}");
    assert!(errs.iter().any(|e| e.contains("compact=")), "{out}");
    // the session survived to run the valid job
    assert_eq!(data_lines(&out).len(), 1, "{out}");
}

#[test]
fn sharded_squeeze_matches_single_engine_on_every_catalog_fractal() {
    // the differential case, end to end through the service: for every
    // catalog fractal, sharded (2 and 4 shards) step hashes must be
    // bit-identical to the single-engine squeeze:<rho> run
    let mut script = String::new();
    let mut ids: Vec<(String, String, String)> = Vec::new(); // (single, s2, s4)
    let mut next = 1u64;
    for spec in catalog::all() {
        let r = if spec.s == 2 { 5 } else { 3 };
        let rho = spec.s;
        let base = format!(
            "fractal={} r={r} steps=4 workers=2 seed=5 density=0.45",
            spec.name
        );
        script.push_str(&format!("{base} engine=squeeze:{rho}\n"));
        script.push_str(&format!("{base} engine=sharded-squeeze:{rho}:2\n"));
        script.push_str(&format!("{base} engine=sharded-squeeze:{rho}:4\n"));
        ids.push((next.to_string(), (next + 1).to_string(), (next + 2).to_string()));
        next += 3;
    }
    script.push_str("quit\n");
    let out = run_session(&script);
    assert!(!out.contains("ERR"), "{out}");
    let rows = data_lines(&out);
    for (spec, (single, s2, s4)) in catalog::all().iter().zip(&ids) {
        let want = hash_of(&rows, single);
        assert_eq!(
            want,
            hash_of(&rows, s2),
            "{}: 2-shard decomposition diverged",
            spec.name
        );
        assert_eq!(
            want,
            hash_of(&rows, s4),
            "{}: 4-shard decomposition diverged",
            spec.name
        );
    }
}

// ---------------------------------------------------------------------
// v2: the typed API through the line protocol (additive verbs)
// ---------------------------------------------------------------------

#[test]
fn v2_banner_is_additive_and_v1_rows_keep_their_shape() {
    let out = run_session("engine=squeeze:4 r=4 steps=2 workers=1 seed=3\nquit\n");
    assert!(out.starts_with("# squeeze coordinator ready"), "{out}");
    assert!(out.contains("# protocol=v2"), "{out}");
    // exactly one data row, with the v1 column count
    let rows = data_lines(&out);
    assert_eq!(rows.len(), 1, "{out}");
    let header_cols = squeeze::coordinator::JobResult::tsv_header()
        .split('\t')
        .count();
    assert_eq!(rows[0].split('\t').count(), header_cols, "{out}");
}

#[test]
fn async_submit_wait_matches_the_sync_twin_hash() {
    let out = run_session(
        "engine=squeeze-bits:4:3 r=5 steps=4 workers=2 seed=5\n\
         async=1\n\
         engine=squeeze-bits:4:3 r=5 steps=4 workers=2 seed=5\n\
         engine=squeeze:4 r=5 steps=4 workers=2 seed=5\n\
         wait 3\n\
         wait 2\n\
         quit\n",
    );
    assert!(!out.contains("ERR"), "{out}");
    assert!(out.contains("JOB 2 submitted"), "{out}");
    assert!(out.contains("JOB 3 submitted"), "{out}");
    let rows: Vec<&str> = out
        .lines()
        .filter(|l| !l.starts_with('#') && l.split('\t').count() > 3)
        .collect();
    assert_eq!(rows.len(), 3, "{out}");
    let hash = |id: &str| {
        rows.iter()
            .find(|l| l.split('\t').next() == Some(id))
            .unwrap_or_else(|| panic!("no row for job {id}"))
            .split('\t')
            .last()
            .unwrap()
    };
    assert_eq!(hash("1"), hash("2"), "{out}");
    assert_eq!(hash("1"), hash("3"), "{out}");
}

#[test]
fn poll_and_cancel_answer_structured_job_lines() {
    let out = run_session(
        "async=1\n\
         engine=squeeze:16 r=8 steps=200000 workers=1 seed=1\n\
         poll 1\n\
         cancel 1\n\
         wait 1\n\
         poll 99\n\
         quit\n",
    );
    // poll answers a JOB line whatever phase the job is in
    assert!(out.lines().any(|l| l.starts_with("JOB 1 ")), "{out}");
    assert!(out.contains("JOB 1 cancel requested"), "{out}");
    assert!(out.contains("ERR 99 unknown job 99"), "{out}");
}

#[test]
fn session_verbs_round_trip_all_layouts_through_serve() {
    // open/step/close for byte+packed, single+sharded: every session's
    // final hash must equal the one-shot v1 job's hash
    let mut script = String::from("engine=squeeze:4 r=5 steps=4 workers=2 seed=5\n");
    for engine in ["squeeze:4", "squeeze-bits:4", "sharded-squeeze:4:3", "squeeze-bits:4:3"] {
        script.push_str(&format!("open engine={engine} r=5 workers=2 seed=5\n"));
    }
    for sid in 1..=4 {
        script.push_str(&format!("step {sid} 4\n"));
    }
    for sid in 1..=4 {
        script.push_str(&format!("close {sid}\n"));
    }
    script.push_str("quit\n");
    let out = run_session(&script);
    assert!(!out.contains("ERR"), "{out}");
    let job_hash = data_lines(&out)
        .iter()
        .find(|l| l.split('\t').count() > 3)
        .map(|l| l.split('\t').last().unwrap().to_string())
        .expect("job row");
    let closes: Vec<&str> = out.lines().filter(|l| l.starts_with("CLOSED")).collect();
    assert_eq!(closes.len(), 4, "{out}");
    for line in closes {
        assert!(line.contains("steps=4"), "{out}");
        assert!(
            line.contains(&format!("hash={job_hash}")),
            "session diverged from the v1 job: {line}\n{out}"
        );
    }
}

#[test]
fn snapshot_restore_through_serve_is_bit_identical_for_packed_sharded() {
    let out = run_session(
        "open engine=squeeze-bits:4:3 r=5 workers=2 seed=5\n\
         step 1 3\n\
         snapshot 1\n\
         step 1 2\n\
         close 1\n\
         quit\n",
    );
    assert!(!out.contains("ERR"), "{out}");
    let token = out
        .lines()
        .find(|l| l.starts_with("SNAPSHOT 1 "))
        .and_then(|l| l.split_whitespace().nth(2))
        .expect("snapshot token");
    let final_hash = out
        .lines()
        .find(|l| l.starts_with("CLOSED 1"))
        .and_then(|l| l.split("hash=").nth(1))
        .expect("close line")
        .to_string();
    // a brand-new serve process restores the token and replays
    let out2 = run_session(&format!("restore {token}\nstep 1 2\nclose 1\nquit\n"));
    assert!(!out2.contains("ERR"), "{out2}");
    let restored = out2.lines().find(|l| l.starts_with("SESSION 1")).unwrap();
    assert!(restored.contains("steps=3"), "{out2}");
    let replay_hash = out2
        .lines()
        .find(|l| l.starts_with("CLOSED 1"))
        .and_then(|l| l.split("hash=").nth(1))
        .expect("close line");
    assert_eq!(replay_hash, final_hash, "{out}\n---\n{out2}");
}

#[test]
fn metrics_verb_dumps_the_multiplexer_gauges() {
    let out = run_session(
        "open engine=squeeze:4 r=4 workers=1 seed=1\n\
         step 1 2\n\
         metrics\n\
         quit\n",
    );
    let metrics_line = out
        .lines()
        .find(|l| l.contains("sessions="))
        .expect("metrics line");
    assert!(metrics_line.contains("sessions=1"), "{out}");
    assert!(metrics_line.contains("progress_steps=2"), "{out}");
    assert!(metrics_line.contains("budget="), "{out}");
    assert!(metrics_line.contains("inflight=0"), "{out}");
}
