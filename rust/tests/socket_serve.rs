//! Socket front-end integration: N concurrent TCP clients running
//! interleaved v2 sessions must be bit-identical to the same workload
//! run serially through one in-process serve, and maps evicted by the
//! LRU byte budget must rebuild to bit-identical stepping.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use squeeze::coordinator::{
    serve_session, Coordinator, CoordinatorConfig, JobSpec, SocketServer,
};

const CLIENTS: u64 = 4;
const SESSIONS_PER_CLIENT: u64 = 2;
const STEPS: u32 = 3;

/// Client `c`'s session-`k` open line: distinct seeds everywhere,
/// rotating levels so clients share map-cache keys with each other.
fn open_line(c: u64, k: u64) -> String {
    format!(
        "open engine=squeeze:4 r={} workers=1 seed={} density=0.4",
        4 + ((c + k) % 3),
        10 * c + k
    )
}

/// Lock-step line-protocol client over TCP.
struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    fn connect(endpoint: &str) -> Client {
        let stream = TcpStream::connect(endpoint).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        let mut c = Client { reader, stream };
        for _ in 0..3 {
            let banner = c.read_line();
            assert!(banner.starts_with('#'), "{banner}");
        }
        c
    }

    fn read_line(&mut self) -> String {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("read");
        assert!(!line.is_empty(), "server hung up early");
        line.trim_end().to_string()
    }

    fn request(&mut self, line: &str) -> String {
        self.stream.write_all(line.as_bytes()).expect("write");
        self.stream.write_all(b"\n").expect("write");
        self.read_line()
    }
}

fn hash_of(line: &str) -> String {
    line.split_whitespace()
        .find_map(|t| t.strip_prefix("hash="))
        .unwrap_or_else(|| panic!("no hash= in {line:?}"))
        .to_string()
}

/// The serial twin: every client's workload, one after another, through
/// `serve_session` on one in-process coordinator. Session ids are
/// deterministic here (1, 2, 3, … in open order), so the scripts can be
/// written up front. Returns `hash[client][session]`.
fn serial_reference() -> Vec<Vec<String>> {
    let coord = Coordinator::new(2);
    let mut hashes = Vec::new();
    for c in 0..CLIENTS {
        let mut script = String::new();
        let first_sid = c * SESSIONS_PER_CLIENT + 1;
        for k in 0..SESSIONS_PER_CLIENT {
            script.push_str(&open_line(c, k));
            script.push('\n');
        }
        for k in 0..SESSIONS_PER_CLIENT {
            script.push_str(&format!("step {} {STEPS}\n", first_sid + k));
        }
        for k in 0..SESSIONS_PER_CLIENT {
            script.push_str(&format!("close {}\n", first_sid + k));
        }
        let mut out = Vec::new();
        serve_session(&coord, script.as_bytes(), &mut out).unwrap();
        let out = String::from_utf8(out).unwrap();
        assert!(!out.contains("ERR"), "{out}");
        let closed: Vec<String> = out
            .lines()
            .filter(|l| l.starts_with("CLOSED "))
            .map(hash_of)
            .collect();
        assert_eq!(closed.len(), SESSIONS_PER_CLIENT as usize, "{out}");
        hashes.push(closed);
    }
    hashes
}

#[test]
fn concurrent_tcp_clients_match_the_serial_in_process_serve() {
    let want = serial_reference();
    let server = SocketServer::bind("127.0.0.1:0", CoordinatorConfig::default()).unwrap();
    let endpoint = server.endpoint().to_string();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let endpoint = endpoint.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(&endpoint);
                // open → step → close, reading each sid off the wire
                // (ids interleave across clients on the shared
                // coordinator, so nothing can be assumed up front)
                let mut sids = Vec::new();
                for k in 0..SESSIONS_PER_CLIENT {
                    let resp = client.request(&open_line(c, k));
                    assert!(resp.starts_with("SESSION "), "{resp}");
                    sids.push(
                        resp.split_whitespace().nth(1).unwrap().parse::<u64>().unwrap(),
                    );
                }
                for &sid in &sids {
                    let resp = client.request(&format!("step {sid} {STEPS}"));
                    assert!(resp.starts_with("STEP "), "{resp}");
                }
                let mut hashes = Vec::new();
                for &sid in &sids {
                    let resp = client.request(&format!("close {sid}"));
                    assert!(resp.starts_with("CLOSED "), "{resp}");
                    hashes.push(hash_of(&resp));
                }
                let _ = client.stream.write_all(b"quit\n");
                hashes
            })
        })
        .collect();
    let got: Vec<Vec<String>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    server.shutdown();
    assert_eq!(got, want, "socket serving changed simulation results");
}

#[test]
fn stepall_over_a_socket_matches_per_session_steps() {
    let server = SocketServer::bind("127.0.0.1:0", CoordinatorConfig::default()).unwrap();
    let mut client = Client::connect(server.endpoint());
    let mut sids = Vec::new();
    for k in 0..2 {
        let resp = client.request(&open_line(0, k));
        sids.push(resp.split_whitespace().nth(1).unwrap().parse::<u64>().unwrap());
    }
    let batch = client.request("stepall 2");
    assert!(batch.starts_with("BATCH stepped sessions=2 errors=0"), "{batch}");
    let swept: Vec<String> = sids
        .iter()
        .map(|sid| hash_of(&client.request(&format!("close {sid}"))))
        .collect();
    let _ = client.stream.write_all(b"quit\n");
    server.shutdown();
    // twin: same sessions advanced with per-session `step SID 2`
    let server = SocketServer::bind("127.0.0.1:0", CoordinatorConfig::default()).unwrap();
    let mut client = Client::connect(server.endpoint());
    let mut sids = Vec::new();
    for k in 0..2 {
        let resp = client.request(&open_line(0, k));
        sids.push(resp.split_whitespace().nth(1).unwrap().parse::<u64>().unwrap());
    }
    let stepped: Vec<String> = sids
        .iter()
        .map(|sid| {
            client.request(&format!("step {sid} 2"));
            hash_of(&client.request(&format!("close {sid}")))
        })
        .collect();
    let _ = client.stream.write_all(b"quit\n");
    server.shutdown();
    assert_eq!(swept, stepped);
}

#[test]
fn begin_shutdown_mid_stepall_completes_the_batch_and_drains() {
    // a 5ms injected delay per step makes the 2-session stepall slow
    // enough (>= 300ms) that the shutdown reliably begins mid-batch
    let config = CoordinatorConfig {
        faults: Some("worker:delay=5ms@n=1".to_string()),
        ..Default::default()
    };
    let mut server = SocketServer::bind("127.0.0.1:0", config).unwrap();
    let endpoint = server.endpoint().to_string();
    let mut client = Client::connect(&endpoint);
    let mut sids = Vec::new();
    for k in 0..2 {
        let resp = client.request(&open_line(0, k));
        sids.push(resp.split_whitespace().nth(1).unwrap().parse::<u64>().unwrap());
    }
    client.stream.write_all(b"stepall 30\n").unwrap();
    std::thread::sleep(Duration::from_millis(50));
    server.begin_shutdown();
    // new connects are refused mid-drain...
    let refused = match TcpStream::connect(&endpoint) {
        Err(_) => true,
        Ok(mut s) => {
            let mut buf = String::new();
            let _ = s.read_to_string(&mut buf);
            buf.is_empty()
        }
    };
    assert!(refused, "listener still answering after begin_shutdown");
    // ...while the in-flight batch completes in full, no errors
    let batch = client.read_line();
    assert!(batch.starts_with("BATCH stepped sessions=2 errors=0"), "{batch}");
    let hashes: Vec<String> = sids
        .iter()
        .map(|sid| hash_of(&client.request(&format!("close {sid}"))))
        .collect();
    let _ = client.stream.write_all(b"quit\n");
    assert!(server.drain(Duration::from_secs(10)), "connection never drained");
    server.shutdown();
    // the injected delays cost time, never state: the drained batch
    // matches a fault-free serial twin
    let twin = Coordinator::new(2);
    let want: Vec<String> = (0..2)
        .map(|k| {
            let line = open_line(0, k);
            let spec =
                JobSpec::parse_line(0, line.strip_prefix("open ").unwrap()).unwrap();
            let info = twin.open(spec).unwrap();
            twin.step(info.sid, 30).unwrap();
            format!("{:#018x}", twin.close(info.sid).unwrap().state_hash)
        })
        .collect();
    assert_eq!(hashes, want, "shutdown race changed simulation results");
}

#[test]
fn evicted_and_rebuilt_maps_step_bit_identically() {
    // the differential: a cache squeezed to a 1-byte budget (every new
    // key evicts the previous entry) vs an unbounded one
    let run = |cache_bytes: Option<u64>| -> (Vec<u64>, u64) {
        let coord = Coordinator::with_config(CoordinatorConfig {
            budget: 1,
            pool_threads: 0,
            cache_bytes,
            ..Default::default()
        });
        let mut hashes = Vec::new();
        for i in 0..6u64 {
            let line = format!(
                "engine=squeeze:4 r={} workers=1 seed={} density=0.4",
                4 + (i % 3),
                i
            );
            let spec = JobSpec::parse_line(0, &line).unwrap();
            let info = coord.open(spec).unwrap();
            coord.step(info.sid, 2).unwrap();
            let done = coord.close(info.sid).unwrap();
            hashes.push(done.state_hash);
        }
        (hashes, coord.map_cache().stats().evictions)
    };
    let (unbounded, no_evictions) = run(None);
    let (tiny, evictions) = run(Some(1));
    assert_eq!(no_evictions, 0);
    assert!(evictions > 0, "1-byte budget must evict between keys");
    assert_eq!(unbounded, tiny, "rebuilt maps diverged from originals");
}
